"""CESM-like synthetic 2-D scalar fields (paper Sec. V datasets).

CESM data is not available offline; these generators produce band-limited
Gaussian random fields and vortex superpositions at the paper's exact grid
sizes, with critical-point densities in the same regime (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# paper Table I grids
DATASETS: Dict[str, Tuple[int, int]] = {
    "ATM": (1800, 3600),
    "CLIMATE": (768, 1152),
    "ICE": (384, 320),
    "LAND": (192, 288),
    "OCEAN": (384, 320),
}


def gaussian_random_field(ny: int, nx: int, power: float = 3.0,
                          seed: int = 0) -> np.ndarray:
    """Band-limited GRF via spectral filtering; values normalized to [0,1]."""
    rng = np.random.default_rng(seed)
    white = rng.standard_normal((ny, nx))
    fy = np.fft.fftfreq(ny)[:, None]
    fx = np.fft.fftfreq(nx)[None, :]
    k = np.sqrt(fy * fy + fx * fx)
    k[0, 0] = 1e-6
    amp = k ** (-power / 2.0)
    amp[0, 0] = 0.0
    f = np.real(np.fft.ifft2(np.fft.fft2(white) * amp))
    f = (f - f.min()) / max(f.max() - f.min(), 1e-30)
    return f.astype(np.float32)


def vortex_field(ny: int, nx: int, n_vortices: int = 40,
                 seed: int = 0) -> np.ndarray:
    """Superposed Gaussian bumps/dips — dense extrema + saddles."""
    rng = np.random.default_rng(seed)
    y, x = np.meshgrid(np.linspace(0, 1, ny), np.linspace(0, 1, nx),
                       indexing="ij")
    f = np.zeros((ny, nx), np.float64)
    for _ in range(n_vortices):
        cy, cx = rng.random(2)
        s = rng.uniform(0.02, 0.12)
        a = rng.uniform(-1.0, 1.0)
        f += a * np.exp(-((y - cy) ** 2 + (x - cx) ** 2) / (2 * s * s))
    f = (f - f.min()) / max(f.max() - f.min(), 1e-30)
    return f.astype(np.float32)


def multiscale_field(ny: int, nx: int, seed: int = 0) -> np.ndarray:
    """GRF + vortices + mild noise — the hardest topology case."""
    f = (0.6 * gaussian_random_field(ny, nx, 3.0, seed)
         + 0.3 * vortex_field(ny, nx, 60, seed + 1))
    rng = np.random.default_rng(seed + 2)
    f = f + 0.02 * rng.standard_normal((ny, nx)).astype(np.float32)
    f = (f - f.min()) / max(f.max() - f.min(), 1e-30)
    return f.astype(np.float32)


def make_dataset(name: str, n_fields: int = 4, seed: int = 0,
                 scale: float = 1.0):
    """Fields for a named CESM-like dataset (paper grid sizes)."""
    ny, nx = DATASETS[name]
    gens = [gaussian_random_field, vortex_field, multiscale_field]
    out = []
    for i in range(n_fields):
        g = gens[i % len(gens)]
        out.append(scale * g(ny, nx, seed=seed * 1000 + i))
    return out
