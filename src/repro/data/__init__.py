from repro.data.fields import (DATASETS, gaussian_random_field, vortex_field,
                               multiscale_field, make_dataset)
from repro.data.synthetic import MarkovTokens, token_batches

__all__ = ["DATASETS", "gaussian_random_field", "vortex_field",
           "multiscale_field", "make_dataset", "MarkovTokens",
           "token_batches"]
