"""Synthetic token pipeline: learnable Markov-chain language.

Deterministic, seekable (step -> batch) so a restarted job replays the
exact same data order — a requirement for reproducible fault-tolerant
training.  The first-order Markov structure gives a ~100M model something
real to learn in a few hundred steps (examples/train_lm.py shows the loss
dropping toward the chain's entropy rate).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class MarkovTokens:
    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.default_rng(seed)
        # sparse-ish transition matrix with a few likely successors per token
        t = rng.dirichlet(np.full(vocab, concentration), size=vocab)
        self.trans = t.astype(np.float64)
        self.vocab = vocab
        # entropy rate (bits -> nats) for reference
        p_stat = np.full(vocab, 1.0 / vocab)
        for _ in range(50):
            p_stat = p_stat @ self.trans
        h = -(self.trans * np.log(np.maximum(self.trans, 1e-12))).sum(1)
        self.entropy_rate = float((p_stat * h).sum())

    def batch(self, step: int, batch_size: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(hash((step, 1234)) % (2 ** 31))
        toks = np.empty((batch_size, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        # vectorized inverse-cdf sampling per step
        cdf = np.cumsum(self.trans, axis=1)
        for t in range(1, seq_len):
            u = rng.random(batch_size)
            toks[:, t] = np.array(
                [np.searchsorted(cdf[toks[i, t - 1]], u[i])
                 for i in range(batch_size)], np.int32)
        np.clip(toks, 0, self.vocab - 1, out=toks)
        return toks


def token_batches(cfg, batch_size: int, seq_len: int, seed: int = 0,
                  start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Iterator of jit-ready batches for any arch frontend."""
    rng = np.random.default_rng(seed)
    markov = MarkovTokens(min(cfg.vocab_size, 512), seed=seed)
    step = start_step
    while True:
        toks = markov.batch(step, batch_size, seq_len)
        if cfg.frontend == "audio_frames":
            emb = rng.standard_normal(
                (batch_size, seq_len, cfg.d_model)).astype(np.float32)
            yield {"embeds": emb, "labels": toks}
        elif cfg.frontend == "vision_patches":
            npre = cfg.num_prefix_embeds
            emb = rng.standard_normal(
                (batch_size, npre, cfg.d_model)).astype(np.float32)
            yield {"patch_embeds": emb,
                   "tokens": toks[:, : seq_len - npre]}
        else:
            yield {"tokens": toks}
        step += 1
