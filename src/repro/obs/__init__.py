"""``repro.obs`` — zero-sync tracing, counters, and profile export.

Runtime visibility for every production path (compress/decompress
stages, the packed ring wire, the serve engine, the async checkpoint
writer) under one hard constraint: instrumentation must add **zero**
host syncs on the hot paths (PR 7's ``transfer_guard`` and
``d2h_bytes_per_compress`` gates stay green with obs enabled).  Metrics
are therefore either trace-time/static (shapes, widths, bucket choices,
byte formulas) or host values read back at EXISTING sync points (end of
a serve sweep, the classic compressor's width read, the checkpoint
writer's commit).

Enable with the ``REPRO_OBS=1`` env var, ``ArchConfig.obs``, ``launch.
train --obs``, or :func:`enable`.  Disabled (the default), every entry
point short-circuits on one flag read — no allocation, no lock.

Surfaces:

  * :func:`span` — structured wall-clock phases with nesting (bridged to
    ``jax.profiler.TraceAnnotation`` so XLA profiles show them);
  * :func:`counter_add` / :func:`gauge_set` / :func:`observe` — low-
    overhead counters, last-write gauges, streaming histograms;
  * :func:`snapshot` / :func:`summary_line` — pull-style reads (the
    train loop's periodic ``[obs]`` lines, the serve report);
  * :func:`export_chrome_trace` / :func:`export_jsonl` /
    :func:`configure` — Perfetto trace files and JSONL event sinks.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

from repro.obs.export import (chrome_trace_doc, export_chrome_trace,
                              export_jsonl)
from repro.obs.registry import (Registry, default_registry, disable, enable,
                                enabled, set_enabled)
from repro.obs.spans import NULL_SPAN, Span, span

__all__ = [
    "Registry", "Span", "NULL_SPAN", "span", "enabled", "enable", "disable",
    "set_enabled", "default_registry", "counter_add", "gauge_set", "observe",
    "error", "snapshot", "summary_line", "events", "reset", "configure",
    "chrome_trace_doc", "export_chrome_trace", "export_jsonl",
]


def counter_add(name: str, value: float = 1.0) -> None:
    """Add to a monotonic counter (no-op when disabled)."""
    if enabled():
        default_registry().counter_add(name, value)


def gauge_set(name: str, value: float) -> None:
    """Set a last-write-wins gauge (no-op when disabled)."""
    if enabled():
        default_registry().gauge_set(name, value)


def observe(name: str, value: float) -> None:
    """Feed one sample into a streaming histogram (no-op when disabled)."""
    if enabled():
        default_registry().observe(name, value)


def error(name: str, message: str, **attrs: Any) -> None:
    """Record an error event + ``<name>.errors`` counter (no-op when
    disabled)."""
    if enabled():
        default_registry().error(name, message, **attrs)


def snapshot() -> dict:
    """Pull-style read of all counters/gauges/histograms recorded so far."""
    return default_registry().snapshot()


def summary_line(prefixes: Optional[Sequence[str]] = None) -> str:
    """Compact one-line ``k=v`` report (the train loop's ``[obs]`` line)."""
    return default_registry().summary_line(prefixes)


def events() -> list:
    """All buffered span/error events (Chrome-trace-shaped dicts)."""
    return default_registry().events()


def reset() -> None:
    """Clear every metric and event (tests / bench isolation)."""
    default_registry().reset()


def configure(jsonl: Optional[str] = None,
              enable_obs: Optional[bool] = None) -> None:
    """Process-level obs setup: optionally flip the enable flag and/or
    open a streaming JSONL event sink."""
    if enable_obs is not None:
        set_enabled(enable_obs)
    if jsonl is not None:
        default_registry().open_jsonl(jsonl)
