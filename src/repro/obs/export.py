"""Profile export: Chrome-trace (Perfetto) files and JSONL event dumps.

``export_chrome_trace(path)`` writes the registry's span events in the
Chrome ``trace_event`` JSON format — open the file at https://ui.perfetto
.dev (or chrome://tracing) to see the span timeline: one track per
thread (the step loop and the checkpoint writer thread land on separate
tracks), span nesting rendered as stacked slices, counters appended as a
final metadata event.

``export_jsonl(path)`` dumps the buffered events one JSON object per
line (the streaming alternative is ``repro.obs.configure(jsonl=...)``,
which mirrors events to a sink file as they complete).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional

from repro.obs import registry as _reg


def _thread_meta(pid: int, tids) -> list:
    """Thread-name metadata events so Perfetto labels the tracks."""
    main = threading.main_thread().ident
    out = []
    for i, tid in enumerate(sorted(tids)):
        name = "main" if tid == main else f"thread-{i}"
        out.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "args": {"name": name}})
    return out


def chrome_trace_doc(reg: Optional[_reg.Registry] = None) -> dict:
    """Build the ``{"traceEvents": [...]}`` document in memory."""
    reg = reg if reg is not None else _reg.default_registry()
    events = reg.events()
    snap = reg.snapshot()
    pids = {ev.get("pid", os.getpid()) for ev in events} or {os.getpid()}
    tids = {ev.get("tid", 0) for ev in events}
    meta = []
    for pid in pids:
        meta.extend(_thread_meta(pid, tids))
    return {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {"counters": snap["counters"],
                      "gauges": snap["gauges"]},
    }


def export_chrome_trace(path: str,
                        reg: Optional[_reg.Registry] = None) -> str:
    """Write a Perfetto-loadable Chrome trace file; returns ``path``."""
    doc = chrome_trace_doc(reg)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def export_jsonl(path: str, reg: Optional[_reg.Registry] = None) -> str:
    """Dump all buffered events as JSON lines; returns ``path``."""
    reg = reg if reg is not None else _reg.default_registry()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        for ev in reg.events():
            f.write(json.dumps(ev) + "\n")
    return path
