"""Structured spans: wall-clock phases with nesting, profiler bridging.

``span(name, **attrs)`` is the one instrumentation primitive the hot
paths use.  Enabled, it

  * records a Chrome-trace complete event (``ph="X"``, µs timestamps,
    per-thread ``tid`` and nesting ``depth``) into the registry,
  * feeds the duration into the ``<name>`` histogram (seconds), and
  * enters a ``jax.profiler.TraceAnnotation`` so the same phase shows up
    on the host timeline of an XLA profile (near-free when no profiler
    trace is active).

Disabled, ``span()`` returns a shared no-op context manager — one flag
read, no allocation.

A span around an async-dispatching JAX call times the DISPATCH, not the
device compute; that is the documented semantics (the device story comes
from the profiler annotations + ``jax.named_scope`` regions inside the
jitted stages).  Spans never read device values, so instrumented paths
keep PR 7's zero-sync guarantee and trace cleanly under an enclosing
``jax.jit`` (the span then measures trace time, once).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, Optional

from repro.obs import registry as _reg

try:                                      # profiler bridge (optional)
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:                         # pragma: no cover - old/absent jax
    _TraceAnnotation = None


class _NullSpan:
    """Shared no-op span for the disabled path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "cat", "args", "_reg", "_t0", "_ts", "_depth",
                 "_annot")

    def __init__(self, name: str, cat: str, args: Dict[str, Any],
                 reg: _reg.Registry) -> None:
        self.name = name
        self.cat = cat
        self.args = args
        self._reg = reg
        self._annot = None

    def __enter__(self) -> "Span":
        reg = self._reg
        self._depth = reg._push()
        self._ts = reg.now_us()
        self._t0 = time.perf_counter()
        if _TraceAnnotation is not None:
            self._annot = _TraceAnnotation(self.name)
            self._annot.__enter__()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        dur_s = time.perf_counter() - self._t0
        if self._annot is not None:
            self._annot.__exit__(exc_type, exc, tb)
        reg = self._reg
        reg._pop()
        ev: Dict[str, Any] = {
            "name": self.name, "cat": self.cat, "ph": "X",
            "ts": self._ts, "dur": dur_s * 1e6,
            "pid": os.getpid(), "tid": threading.get_ident(),
            "depth": self._depth,
        }
        if self.args:
            ev["args"] = self.args
        if exc_type is not None:
            ev.setdefault("args", {})["error"] = exc_type.__name__
        reg.record_event(ev)
        reg.observe(self.name, dur_s)
        return False


def span(name: str, cat: str = "span",
         reg: Optional[_reg.Registry] = None, **attrs: Any):
    """Context manager timing one phase (no-op when obs is disabled)."""
    if not _reg.enabled():
        return NULL_SPAN
    return Span(name, cat, attrs, reg if reg is not None
                else _reg.default_registry())
