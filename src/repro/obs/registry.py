"""Zero-sync observability registry: counters, gauges, histograms, spans.

One process-wide :class:`Registry` (module-level default) collects

  * **counters** — monotonic adds (``counter_add``);
  * **gauges** — last-write-wins scalars (``gauge_set``), the shape
    trace-time wire models record (a traced-once function must not
    accumulate per-execution values it cannot see);
  * **histograms** — streaming count/sum/min/max/last (``observe``);
  * **span events** — Chrome-trace-ready complete events with per-thread
    nesting depth, recorded by :mod:`repro.obs.spans`.

The hard constraint (PR 7's zero-sync guarantee) is enforced by POLICY,
not mechanism: nothing in this module touches a device value — every
recorded number is a host float the caller already had, either
trace-time/static (shapes, widths, byte formulas) or read back at an
existing sync point (end of a serve sweep, the checkpoint writer's
commit, the classic compressor's width read).  Instrumented hot paths
therefore add **zero** host syncs, and the disabled path short-circuits
before building any event (``enabled()`` is one attribute read).

Thread-safety: every mutation takes the registry lock — the checkpoint
async writer records spans from its daemon thread concurrently with the
step loop.  Nesting depth is tracked per thread (``threading.local``).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, IO, List, Optional, Sequence

_TRUTHY = ("1", "true", "yes", "on")

MAX_EVENTS = 200_000        # span-event ring bound; overflow counts as drops


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "0").strip().lower() in _TRUTHY


class _Hist:
    """Streaming histogram summary (no buckets: count/sum/min/max/last)."""

    __slots__ = ("count", "total", "vmin", "vmax", "last")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.last = 0.0

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.vmin = v if v < self.vmin else self.vmin
        self.vmax = v if v > self.vmax else self.vmax
        self.last = v

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "mean": self.total / self.count if self.count else 0.0,
                "last": self.last}


class Registry:
    """Thread-safe metric + span-event store."""

    def __init__(self, max_events: int = MAX_EVENTS) -> None:
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.max_events = max_events
        self._origin = time.perf_counter()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Hist] = {}
        self._events: List[Dict[str, Any]] = []
        self._dropped = 0
        self._sink: Optional[IO[str]] = None
        self._sink_path: Optional[str] = None

    # -- time base ----------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since this registry's origin (span timestamps)."""
        return (time.perf_counter() - self._origin) * 1e6

    # -- per-thread span depth (used by obs.spans) --------------------------

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def _push(self) -> int:
        d = getattr(self._tls, "depth", 0)
        self._tls.depth = d + 1
        return d

    def _pop(self) -> None:
        self._tls.depth = max(getattr(self._tls, "depth", 1) - 1, 0)

    # -- metrics ------------------------------------------------------------

    def counter_add(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + value

    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Hist()
            h.add(value)

    # -- events -------------------------------------------------------------

    def record_event(self, ev: Dict[str, Any]) -> None:
        """Append one Chrome-trace-shaped event (and mirror it to the
        JSONL sink when one is configured)."""
        with self._lock:
            if len(self._events) >= self.max_events:
                self._dropped += 1
            else:
                self._events.append(ev)
            if self._sink is not None:
                try:
                    self._sink.write(json.dumps(ev) + "\n")
                except (OSError, ValueError):
                    self._sink = None      # dead sink: stop writing, keep obs

    def error(self, name: str, message: str, **attrs: Any) -> None:
        """Record an error as an instant event + ``<name>.errors`` counter
        (attributable from periodic train-loop obs lines)."""
        args = dict(attrs)
        args["message"] = message
        self.record_event({"name": name, "cat": "error", "ph": "i",
                           "ts": self.now_us(), "pid": os.getpid(),
                           "tid": threading.get_ident(), "s": "t",
                           "args": args})
        self.counter_add(f"{name}.errors", 1)

    # -- sinks / snapshots --------------------------------------------------

    def open_jsonl(self, path: str) -> None:
        """Stream every subsequent event to ``path`` as JSON lines."""
        with self._lock:
            if self._sink is not None:
                self._sink.close()
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._sink = open(path, "a")
            self._sink_path = path

    def close_jsonl(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.close()
                self._sink = None
                self._sink_path = None

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def snapshot(self) -> Dict[str, Any]:
        """Pull-style read of everything recorded so far (host-only)."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.summary()
                               for k, h in self._hists.items()},
                "events": len(self._events),
                "dropped_events": self._dropped,
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._events.clear()
            self._dropped = 0
            self._origin = time.perf_counter()

    def summary_line(self, prefixes: Optional[Sequence[str]] = None) -> str:
        """One compact ``k=v`` report line (counters + gauges + histogram
        means), optionally filtered to name prefixes."""
        snap = self.snapshot()
        parts: List[str] = []

        def keep(name: str) -> bool:
            return prefixes is None or any(name.startswith(p)
                                           for p in prefixes)

        for k in sorted(snap["counters"]):
            if keep(k):
                parts.append(f"{k}={_fmt(snap['counters'][k])}")
        for k in sorted(snap["gauges"]):
            if keep(k):
                parts.append(f"{k}={_fmt(snap['gauges'][k])}")
        for k in sorted(snap["histograms"]):
            if keep(k):
                h = snap["histograms"][k]
                parts.append(f"{k}.mean={_fmt(h['mean'])}")
        return " ".join(parts) if parts else "(no metrics)"


def _fmt(v: float) -> str:
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.4g}"


# -- module state: the default registry + the enable flag -------------------

_default = Registry()
_enabled = _env_enabled()


def default_registry() -> Registry:
    return _default


def enabled() -> bool:
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def set_enabled(flag: bool) -> None:
    global _enabled
    _enabled = bool(flag)
