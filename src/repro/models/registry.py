"""Architecture registry: --arch <id> -> ArchConfig (+ smoke variants)."""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_IDS: List[str] = [
    "rwkv6_3b",
    "recurrentgemma_2b",
    "minicpm_2b",
    "phi3_mini_3p8b",
    "gemma2_2b",
    "gemma3_4b",
    "arctic_480b",
    "olmoe_1b_7b",
    "musicgen_medium",
    "internvl2_76b",
]


def get_config(arch: str):
    """Full published config for ``--arch <id>``."""
    arch = arch.replace("-", "_").replace(".", "p")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    """Reduced same-family config for CPU smoke tests."""
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def all_configs() -> Dict[str, object]:
    return {a: get_config(a) for a in ARCH_IDS}
