"""Mixture-of-Experts FFN: top-k token-choice routing, sort-based dispatch,
capacity-bounded grouped matmuls, expert parallelism on the 'model' axis.

Dispatch (MaxText/MegaBlocks-style, static shapes):
  1. router softmax -> top-k (weights, expert ids) per token
  2. stable sort assignments by expert id
  3. position-within-expert via segment arithmetic; drop beyond capacity
  4. gather tokens into (E, C, d), grouped einsum (E,C,d)x(E,d,ff)
  5. scatter-add weighted outputs back to tokens

All tensors with a leading E axis carry a 'model' sharding constraint, so
GSPMD partitions the expert compute (EP); the gather/scatter token sides
stay batch-sharded.  Arctic's "dense residual" (dense FFN in parallel with
the MoE) is composed in blocks.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.models.common import dense, ninit, shard


def init_moe(key, cfg):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    return {
        "w_router": ninit(ks[0], (d, e), sc, jnp.float32),
        "w_in": ninit(ks[1], (e, d, ff), sc, cfg.param_dtype),
        "w_gate": ninit(ks[2], (e, d, ff), sc, cfg.param_dtype),
        "w_out": ninit(ks[3], (e, ff, d), 1.0 / math.sqrt(ff), cfg.param_dtype),
    }


def _capacity(n_tokens: int, cfg) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def _route(xt, params, cfg):
    """Router: returns (topw (T,k), topi (T,k), aux)."""
    logits = dense(xt.astype(jnp.float32), params["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renormalize_router:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jax.nn.one_hot(topi[:, 0], cfg.num_experts,
                        dtype=jnp.float32).mean(0)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return topw, topi, aux


def _dispatch_compute_combine(xt, topw, topi, w_in, w_gate, w_out, cfg,
                              n_experts: int, e_offset, cap: int):
    """Sort-based dispatch over ``n_experts`` local experts starting at
    ``e_offset``; returns the combined (T, d) output (local contribs)."""
    t, d = xt.shape
    k = cfg.top_k
    flat_e = topi.reshape(-1) - e_offset
    flat_w = topw.reshape(-1)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    local = (flat_e >= 0) & (flat_e < n_experts)
    sort_key = jnp.where(local, flat_e, n_experts)
    order = jnp.argsort(sort_key, stable=True)
    e_s, tok_s, w_s = (sort_key[order], flat_tok[order], flat_w[order])
    loc_s = local[order]
    pos = jnp.arange(t * k, dtype=jnp.int32)
    seg_start = jax.lax.associative_scan(
        jnp.maximum,
        jnp.where(jnp.concatenate([jnp.array([True]), e_s[1:] != e_s[:-1]]),
                  pos, 0))
    slot = pos - seg_start
    keep = loc_s & (slot < cap)

    safe_e = jnp.where(keep, e_s, 0)
    safe_slot = jnp.where(keep, slot, cap - 1)
    xg = jnp.zeros((n_experts, cap, d), xt.dtype)
    xg = xg.at[safe_e, safe_slot].set(
        jnp.where(keep[:, None], xt[tok_s], 0).astype(xt.dtype))

    h = jnp.einsum("ecd,edf->ecf", xg, w_in.astype(xt.dtype),
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    g = jnp.einsum("ecd,edf->ecf", xg, w_gate.astype(xt.dtype),
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    h = jax.nn.silu(g) * h
    yo = jnp.einsum("ecf,efd->ecd", h, w_out.astype(xt.dtype),
                    preferred_element_type=jnp.float32).astype(xt.dtype)

    contrib = yo[safe_e, safe_slot] * w_s[:, None].astype(xt.dtype)
    contrib = jnp.where(keep[:, None], contrib, 0)
    return jnp.zeros((t, d), xt.dtype).at[tok_s].add(contrib)


def apply_moe_shard_map(params, x, cfg):
    """Expert parallelism with explicit shard_map over 'model'.

    GSPMD cannot partition the sort/scatter dispatch cleanly (it falls back
    to 'involuntary full rematerialization' all-gathers — the baseline's
    dominant collective cost, EXPERIMENTS.md §Perf).  Manual EP makes the
    communication explicit and minimal: router runs replicated, each model
    shard dispatches/computes its E/TP local experts, and ONE psum over
    'model' combines the outputs.
    """
    from repro.models.common import batch_axes_for, get_active_mesh
    mesh = get_active_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or mesh.shape["model"] == 1
            or cfg.num_experts % mesh.shape["model"] != 0):
        return apply_moe(params, x, cfg)

    b, s, d = x.shape
    tp = mesh.shape["model"]
    e_local = cfg.num_experts // tp
    baxes = batch_axes_for(b) or ()
    bspec = P(baxes if baxes else None, None, None)
    # fsdp strategy shards the batch over 'model' too: the EP body then
    # all-gathers its token block over 'model' (cheap — activations are
    # 16x smaller per chip), computes its local experts for ALL tokens,
    # psums, and keeps its own slice back.
    tokens_model_sharded = "model" in baxes

    def body(xb, wr, w_in, w_gate, w_out):
        bl, sl, _ = xb.shape
        xt = xb.reshape(-1, d)
        if tokens_model_sharded:
            xt = jax.lax.all_gather(xt, "model", axis=0, tiled=True)
        topw, topi, aux = _route(xt, {"w_router": wr}, cfg)
        cap = _capacity(xt.shape[0], cfg)
        e_off = jax.lax.axis_index("model") * e_local
        y = _dispatch_compute_combine(xt, topw, topi, w_in, w_gate, w_out,
                                      cfg, e_local, e_off, cap)
        y = jax.lax.psum(y, "model")
        if tokens_model_sharded:
            midx = jax.lax.axis_index("model")
            y = jax.lax.dynamic_slice_in_dim(y, midx * (bl * sl), bl * sl,
                                             axis=0)
        if baxes:
            aux = jax.lax.pmean(aux, baxes)
        return y.reshape(bl, sl, d), aux

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(bspec, P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(bspec, P()),
        check_vma=False,
    )(x, params["w_router"], params["w_in"], params["w_gate"],
      params["w_out"])
    return y, aux


def apply_moe(params, x, cfg):
    """x: (B,S,d) -> (B,S,d), plus load-balancing aux loss."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    # --- routing (f32 for stability) ---
    logits = dense(xt.astype(jnp.float32), params["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    topw, topi = jax.lax.top_k(probs, k)                     # (T, k)
    if cfg.renormalize_router:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = probs.mean(0)
    one_hot_top1 = jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(0)
    aux = e * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    flat_e = topi.reshape(-1)                                # (T*k,)
    flat_w = topw.reshape(-1)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    e_s = flat_e[order]
    tok_s = flat_tok[order]
    w_s = flat_w[order]
    pos = jnp.arange(t * k, dtype=jnp.int32)
    seg_start = jax.lax.associative_scan(
        jnp.maximum,
        jnp.where(jnp.concatenate([jnp.array([True]), e_s[1:] != e_s[:-1]]),
                  pos, 0))
    slot = pos - seg_start                                    # rank in expert
    cap = _capacity(t, cfg)
    keep = slot < cap

    # gather tokens into (E, C, d); dropped slots read token 0 with weight 0
    safe_e = jnp.where(keep, e_s, 0)
    safe_slot = jnp.where(keep, slot, cap - 1)
    xg = jnp.zeros((e, cap, d), x.dtype)
    xg = xg.at[safe_e, safe_slot].set(
        jnp.where(keep[:, None], xt[tok_s], 0).astype(x.dtype))
    xg = shard(xg, "model", None, None)

    # --- grouped expert matmuls (EP over 'model') ---
    h = jnp.einsum("ecd,edf->ecf", xg, params["w_in"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = jax.nn.silu(g) * h
    h = shard(h, "model", None, None)
    yo = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(x.dtype),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    yo = shard(yo, "model", None, None)

    # --- combine: scatter-add weighted expert outputs back to tokens ---
    contrib = yo[safe_e, safe_slot] * w_s[:, None].astype(x.dtype)
    contrib = jnp.where(keep[:, None], contrib, 0)
    y = jnp.zeros((t, d), x.dtype).at[tok_s].add(contrib)
    y = shard(y.reshape(b, s, d), "batch", None, None)
    return y, aux
