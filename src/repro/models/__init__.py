"""Model zoo: composable decoder blocks covering all assigned families."""
from repro.models.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.lm import (init_params, loss_fn, prefill, decode_step,
                             make_caches, forward, param_count)
from repro.models.common import set_active_mesh, get_active_mesh

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "init_params",
           "loss_fn", "prefill", "decode_step", "make_caches", "forward",
           "param_count", "set_active_mesh", "get_active_mesh"]
