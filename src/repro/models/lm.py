"""The language model: embedding -> scanned layer groups -> head.

Layers are stacked into scanned *groups* (one group = one cycle of
cfg.layer_pattern) so compile time and HLO size stay flat in depth; the
remainder layers (depth % pattern) run unrolled as a tail.  The same forward
serves training (loss), prefill (build caches) and decode (one token).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.blocks import apply_block, block_cache, init_block
from repro.models.common import (chunked_softmax_xent, dense, ninit,
                                 rms_norm, shard, softcap)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg, key) -> Dict[str, Any]:
    groups, tail = cfg.pattern_layers()
    n_groups = len(groups)
    keys = jax.random.split(key, 4 + n_groups + len(tail))
    p: Dict[str, Any] = {
        "embed": ninit(keys[0], (cfg.padded_vocab, cfg.d_model),
                       1.0 / math.sqrt(cfg.d_model), cfg.param_dtype),
        "final_ln": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["w_out"] = ninit(keys[1], (cfg.d_model, cfg.padded_vocab),
                           1.0 / math.sqrt(cfg.d_model), cfg.param_dtype)
    if n_groups:
        pattern = cfg.layer_pattern

        def one_group(k):
            ks = jax.random.split(k, len(pattern))
            return tuple(init_block(ks[i], cfg, kind)
                         for i, kind in enumerate(pattern))

        p["groups"] = jax.vmap(one_group)(
            jnp.stack(keys[4:4 + n_groups]))
    if tail:
        p["tail"] = [init_block(keys[4 + n_groups + i], cfg, kind)
                     for i, kind in enumerate(tail)]
    return p


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def rowwise_caches(caches):
    """Convert every KVCache in a (gcaches, tcaches) pair to per-row
    positions (see attention.rowwise_cache) — the continuous-batching serve
    layout where each batch row advances independently.  Recurrent states
    (rwkv / RG-LRU) are already per-row and pass through unchanged."""
    from repro.models.attention import KVCache, rowwise_cache
    gcaches, tcaches = caches
    is_kv = lambda x: isinstance(x, KVCache)   # noqa: E731
    if gcaches is not None:
        gcaches = jax.tree.map(
            lambda c: rowwise_cache(c, stacked=True) if is_kv(c) else c,
            gcaches, is_leaf=is_kv)
    tcaches = [rowwise_cache(c) if is_kv(c) else c for c in tcaches]
    return gcaches, tcaches


def make_caches(cfg, batch: int, max_len: int, spec: bool = False):
    """Decode caches: (stacked group caches, tail cache list)."""
    groups, tail = cfg.pattern_layers()
    n_groups = len(groups)
    gcaches = None
    if n_groups:
        one = tuple(block_cache(cfg, kind, batch, max_len, spec=spec)
                    for kind in cfg.layer_pattern)
        if spec:
            gcaches = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype),
                one)
        else:
            gcaches = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), one)
    tcaches = [block_cache(cfg, kind, batch, max_len, spec=spec)
               for kind in tail]
    return gcaches, tcaches


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _embed_tokens(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return shard(x, "batch", None, None)


def _inputs_to_x(params, cfg, batch: Dict[str, jnp.ndarray]):
    """Resolve token ids / stub-frontend embeddings into (B, S, d)."""
    if cfg.frontend == "audio_frames":
        return shard(batch["embeds"].astype(cfg.activation_dtype),
                     "batch", None, None)
    if cfg.frontend == "vision_patches":
        prefix = batch["patch_embeds"].astype(cfg.activation_dtype)
        toks = _embed_tokens(params, cfg, batch["tokens"])
        return shard(jnp.concatenate([prefix, toks], axis=1),
                     "batch", None, None)
    return _embed_tokens(params, cfg, batch["tokens"])


def forward(params, cfg, x, caches=None, mode: str = "train",
            pos_offset: jnp.ndarray | int = 0):
    """Run the block stack.  Returns (hidden, caches', aux)."""
    groups, tail = cfg.pattern_layers()
    n_groups = len(groups)
    pattern = cfg.layer_pattern
    aux = jnp.float32(0.0)
    gcaches, tcaches = caches if caches is not None else (None, None)

    if n_groups:
        def group_body(carry, xs):
            x, aux = carry
            gp = xs if gcaches is None else xs[0]
            gc = None if gcaches is None else xs[1]
            new_caches = []
            for i, kind in enumerate(pattern):
                x, c, a = apply_block(gp[i], x, cfg, kind,
                                      None if gc is None else gc[i],
                                      pos_offset)
                new_caches.append(c)
                aux = aux + a
            ys = tuple(new_caches) if mode != "train" else None
            return (x, aux), ys

        body = jax.checkpoint(group_body) if (cfg.remat and mode == "train") \
            else group_body
        xs = params["groups"] if gcaches is None \
            else (params["groups"], gcaches)
        if cfg.unroll_groups:
            # costing mode: python loop so XLA cost analysis sees every
            # group (lax.scan bodies are counted once — see dryrun.py)
            ys = []
            carry = (x, aux)
            for gi in range(n_groups):
                xi = jax.tree.map(lambda a: a[gi], xs)
                carry, y = body(carry, xi)
                ys.append(y)
            (x, aux) = carry
            new_g = (jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
                     if ys and ys[0] is not None else None)
        else:
            (x, aux), new_g = jax.lax.scan(body, (x, aux), xs)
    else:
        new_g = None

    new_tail = []
    for i, kind in enumerate(tail):
        c_in = tcaches[i] if tcaches is not None else None
        x, c, a = apply_block(params["tail"][i], x, cfg, kind, c_in,
                              pos_offset)
        new_tail.append(c)
        aux = aux + a

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, (new_g, new_tail), aux


def lm_head_weight(params, cfg):
    return (params["embed"].T if cfg.tie_embeddings else params["w_out"])


def logits_fn(params, cfg, hidden):
    """Full logits for a short (decode-size) hidden: (B, s, V)."""
    logits = dense(hidden, lm_head_weight(params, cfg)).astype(jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab > cfg.vocab_size:
        logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                           logits, -1e30)
    return shard(logits, "batch", None, "model")


# --------------------------------------------------------------------------
# Task heads
# --------------------------------------------------------------------------

def loss_fn(params, cfg, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Next-token loss for any frontend.  See configs/base.py for batches."""
    x = _inputs_to_x(params, cfg, batch)
    h, _, aux = forward(params, cfg, x, mode="train")

    w = lm_head_weight(params, cfg)
    if cfg.frontend == "vision_patches":
        npre = batch["patch_embeds"].shape[1]
        ntok = batch["tokens"].shape[1]
        h_pred = h[:, npre - 1:npre - 1 + ntok, :]
        labels = batch["tokens"]
    elif cfg.frontend == "audio_frames":
        h_pred = h[:, :-1, :]
        labels = batch["labels"][:, 1:]
    else:
        h_pred = h[:, :-1, :]
        labels = batch["tokens"][:, 1:]

    xent = chunked_softmax_xent(h_pred, w, labels, chunk=cfg.loss_chunk,
                                logit_cap=cfg.logit_softcap,
                                real_vocab=cfg.vocab_size,
                                unroll=cfg.unroll_loss)
    return xent + cfg.router_aux_weight * aux


def prefill(params, cfg, batch: Dict[str, jnp.ndarray]):
    """Build decode caches from a prompt; returns (last_logits, caches)."""
    x = _inputs_to_x(params, cfg, batch)
    h, caches, _ = forward(params, cfg, x, mode="prefill")
    return logits_fn(params, cfg, h[:, -1:, :]), caches


def decode_step(params, cfg, tokens, caches):
    """One greedy decode step.  tokens: (B, 1) -> (next (B,1), logits, caches)."""
    x = _embed_tokens(params, cfg, tokens)
    h, caches, _ = forward(params, cfg, x, caches=caches, mode="decode")
    logits = logits_fn(params, cfg, h)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    return nxt, logits, caches
