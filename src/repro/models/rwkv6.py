"""RWKV-6 ("Finch") time-mix and channel-mix blocks — attention-free,
data-dependent per-channel decay [arXiv:2404.05892].

Time-mix recurrence per head (head size cfg.rwkv_head_dim):
    out_t = r_t . (S_t + (u * k_t) x v_t)
    S_t+1 = diag(w_t) S_t + k_t x v_t
with w_t = exp(-exp(w0 + lora_w(x_w))) the data-dependent decay, and the
r/k/v/w/g inputs produced by data-dependent token-shift interpolation
(ddlerp) between x_t and x_{t-1}.

The baseline sequence path is a lax.scan carrying S (B,H,Dh,Dh) — O(1)
memory, exactly the published recurrence.  kernels-level chunked form is a
documented §Perf optimization (EXPERIMENTS.md).  Decode carries (S, last_x)
— O(1) state, which is what makes the long_500k cell runnable.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, ninit, shard


class RWKVState(NamedTuple):
    s: jnp.ndarray        # (B, H, Dh, Dh) wkv state
    x_time: jnp.ndarray   # (B, d) previous token input (time-mix shift)
    x_chan: jnp.ndarray   # (B, d) previous token input (channel-mix shift)


_MIX = ("r", "k", "v", "w", "g")


def init_rwkv(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    dh = cfg.rwkv_head_dim
    h = d // dh
    lora, lora_w = cfg.rwkv_lora, cfg.rwkv_lora * 2
    ks = iter(jax.random.split(key, 32))
    sc = 1.0 / math.sqrt(d)
    p = {
        # ddlerp: shared mu_x + per-stream mu / LoRA pairs
        "mu_x": jnp.zeros((d,), cfg.param_dtype),
        "lora_a": ninit(next(ks), (d, 5 * lora), sc, cfg.param_dtype),
        "lora_b": ninit(next(ks), (5, lora, d), 0.01, cfg.param_dtype),
    }
    for m in _MIX:
        p[f"mu_{m}"] = jnp.zeros((d,), cfg.param_dtype)
    p.update({
        "w_r": ninit(next(ks), (d, d), sc, cfg.param_dtype),
        "w_k": ninit(next(ks), (d, d), sc, cfg.param_dtype),
        "w_v": ninit(next(ks), (d, d), sc, cfg.param_dtype),
        "w_g": ninit(next(ks), (d, d), sc, cfg.param_dtype),
        "w_o": ninit(next(ks), (d, d), sc, cfg.param_dtype),
        # decay: w0 bias + LoRA; init so decay starts ~exp(-exp(-5)) ~ .993
        "w0": jnp.full((d,), -5.0, cfg.param_dtype),
        "wa": ninit(next(ks), (d, lora_w), sc, cfg.param_dtype),
        "wb": ninit(next(ks), (lora_w, d), 0.01, cfg.param_dtype),
        "u": ninit(next(ks), (h, dh), 0.5, cfg.param_dtype),
        "ln_scale": jnp.ones((d,), cfg.param_dtype),
        # channel mix
        "c_mu_k": jnp.zeros((d,), cfg.param_dtype),
        "c_mu_r": jnp.zeros((d,), cfg.param_dtype),
        "c_wk": ninit(next(ks), (d, ff), sc, cfg.param_dtype),
        "c_wv": ninit(next(ks), (ff, d), 1.0 / math.sqrt(ff), cfg.param_dtype),
        "c_wr": ninit(next(ks), (d, d), sc, cfg.param_dtype),
    })
    return p


def init_rwkv_state(cfg, batch: int) -> RWKVState:
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    h = d // dh
    return RWKVState(
        s=jnp.zeros((batch, h, dh, dh), jnp.float32),
        x_time=jnp.zeros((batch, d), cfg.activation_dtype),
        x_chan=jnp.zeros((batch, d), cfg.activation_dtype),
    )


def rwkv_state_spec(cfg, batch: int) -> RWKVState:
    d, dh = cfg.d_model, cfg.rwkv_head_dim
    h = d // dh
    sds = jax.ShapeDtypeStruct
    return RWKVState(s=sds((batch, h, dh, dh), jnp.float32),
                     x_time=sds((batch, d), cfg.activation_dtype),
                     x_chan=sds((batch, d), cfg.activation_dtype))


def _ddlerp(p, x, xx):
    """Data-dependent token-shift interpolation -> dict of 5 mixed inputs."""
    d = x.shape[-1]
    base = x + (xx - x) * p["mu_x"].astype(x.dtype)
    lo = jnp.tanh(dense(base, p["lora_a"]))               # (..., 5*lora)
    lo = lo.reshape(*lo.shape[:-1], 5, -1)
    adj = jnp.einsum("...sr,srd->...sd", lo.astype(x.dtype),
                     p["lora_b"].astype(x.dtype))          # (..., 5, d)
    out = {}
    for i, m in enumerate(_MIX):
        mu = p[f"mu_{m}"].astype(x.dtype) + adj[..., i, :]
        out[m] = x + (xx - x) * mu
    return out


def _group_norm(x, scale, h, dh, eps=1e-5):
    """Per-head layer norm of the wkv output (RWKV's GroupNorm(h))."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], h, dh).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


def _wkv_step(s, r, k, v, w, u):
    """One recurrence step.  s: (B,H,Dh,Dh); r,k,v,w: (B,H,Dh)."""
    kv = k[..., :, None] * v[..., None, :]                # (B,H,Dh,Dh)
    out = jnp.einsum("bhi,bhij->bhj", r, s + u[None, :, :, None] * kv)
    s = w[..., :, None] * s + kv
    return s, out


_CHUNK = 16          # chunk length for the matmul-form WKV
_LOG_W_FLOOR = -4.0  # per-step decay floor (exp(-4*16)=e^-64 stays in f32;
                     # faster decays are numerically zero after 1-2 steps)


def _wkv_chunked(s0, r, k, v, w_log, u, chunk: int = _CHUNK):
    """Matmul-form WKV (GLA-style chunking) — the §Perf 'chunked' path.

    Exact reformulation of the recurrence per chunk of length C:
        out_t = (r_t*P_{t-1}) . S_0  +  sum_{tau<t} (r_t*P_{t-1}) .
                (k_tau/P_tau) v_tau  +  (r_t . u*k_t) v_t
        S_C   = diag(P_C) S_0 + sum_tau diag(P_C/P_tau) k_tau v_tau^T
    with P_t the inclusive decay cumproduct.  Sequential length drops from
    S steps to S/C steps and the inner work becomes MXU-shaped (C x Dh)
    matmuls.  Validated against the scan implementation in
    tests/test_rwkv_chunked.py.

    Args: s0 (B,H,D,D) f32; r,k,v,w_log (S,B,H,D) f32 (w_log = log decay).
    Returns (S_final, out (S,B,H,D)).
    """
    s_len, b, h, d = r.shape
    pad = (-s_len) % chunk
    if pad:
        z = jnp.zeros((pad, b, h, d), r.dtype)
        r, k, v = (jnp.concatenate([x, z]) for x in (r, k, v))
        w_log = jnp.concatenate([w_log, jnp.zeros((pad, b, h, d))])
    n = r.shape[0] // chunk

    def to_chunks(x):
        return x.reshape(n, chunk, b, h, d).transpose(0, 2, 3, 1, 4)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)   # (N,B,H,C,D)
    wl = to_chunks(jnp.maximum(w_log, _LOG_W_FLOOR))

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # strict causal

    def body(s, inp):
        rt, kt, vt, wlt = inp                                # (B,H,C,D)
        lp = jnp.cumsum(wlt, axis=2)                         # logP_t (incl.)
        lp_prev = lp - wlt                                   # logP_{t-1}
        r_dec = rt * jnp.exp(lp_prev)                        # r_t * P_{t-1}
        k_dec = kt * jnp.exp(-lp)                            # k_tau / P_tau
        # intra-chunk attention-like term
        scores = jnp.einsum("bhtd,bhsd->bhts", r_dec, k_dec)
        scores = jnp.where(mask[None, None], scores, 0.0)
        intra = jnp.einsum("bhts,bhsd->bhtd", scores, vt)
        diag = jnp.einsum("bhtd,bhtd->bht", rt, u[None, :, None, :] * kt)
        intra = intra + diag[..., None] * vt
        # inter-chunk term from the carried state
        inter = jnp.einsum("bhtd,bhdj->bhtj", r_dec, s)
        out = inter + intra
        # state update
        lp_c = lp[:, :, -1:, :]                              # logP_C
        k_fin = kt * jnp.exp(lp_c - lp)                      # k_tau*P_C/P_tau
        s = jnp.exp(lp_c[:, :, 0, :, None]) * s + \
            jnp.einsum("bhtd,bhtj->bhdj", k_fin, vt)
        return s, out

    s_final, outs = jax.lax.scan(body, s0, (rc, kc, vc, wl))
    out = outs.transpose(0, 3, 1, 2, 4).reshape(n * chunk, b, h, d)
    return s_final, out[:s_len]


def time_mix(p, x, cfg, state: Optional[RWKVState]
             ) -> Tuple[jnp.ndarray, RWKVState]:
    """RWKV6 attention substitute.  x: (B,S,d)."""
    b, s_len, d = x.shape
    dh = cfg.rwkv_head_dim
    h = d // dh

    if state is None:
        state = init_rwkv_state(cfg, b)
    xx = jnp.concatenate([state.x_time[:, None, :], x[:, :-1, :]], axis=1)
    mixed = _ddlerp(p, x, xx)

    r = dense(mixed["r"], p["w_r"]).reshape(b, s_len, h, dh)
    k = dense(mixed["k"], p["w_k"]).reshape(b, s_len, h, dh)
    v = dense(mixed["v"], p["w_v"]).reshape(b, s_len, h, dh)
    g = jax.nn.silu(dense(mixed["g"], p["w_g"]))
    w_log = -jnp.exp(p["w0"].astype(jnp.float32)
                     + dense(jnp.tanh(dense(mixed["w"], p["wa"])),
                             p["wb"]).astype(jnp.float32))
    w = jnp.exp(w_log).reshape(b, s_len, h, dh)            # decay in (0,1)
    u = p["u"].astype(jnp.float32)

    rf = r.astype(jnp.float32).transpose(1, 0, 2, 3)       # (S,B,H,Dh)
    kf = k.astype(jnp.float32).transpose(1, 0, 2, 3)
    vf = v.astype(jnp.float32).transpose(1, 0, 2, 3)
    wf = w.transpose(1, 0, 2, 3)

    if cfg.rwkv_impl == "chunked" and s_len > 1:
        wl = w_log.reshape(b, s_len, h, dh).transpose(1, 0, 2, 3)
        s_final, outs = _wkv_chunked(state.s, rf, kf, vf, wl, u)
    else:
        def body(s_carry, inp):
            rt, kt, vt, wt = inp
            s_carry, out = _wkv_step(s_carry, rt, kt, vt, wt, u)
            return s_carry, out

        s_final, outs = jax.lax.scan(body, state.s, (rf, kf, vf, wf))
    out = outs.transpose(1, 0, 2, 3).reshape(b, s_len, d)  # (B,S,d)
    out = _group_norm(out, p["ln_scale"], h, dh).astype(x.dtype)
    y = dense(out * g.astype(out.dtype), p["w_o"]).astype(x.dtype)
    new_state = RWKVState(s=s_final, x_time=x[:, -1, :], x_chan=state.x_chan)
    return shard(y, "batch", None, None), new_state


def channel_mix(p, x, cfg, state: RWKVState) -> Tuple[jnp.ndarray, RWKVState]:
    """RWKV6 FFN substitute with token shift.  x: (B,S,d)."""
    xx = jnp.concatenate([state.x_chan[:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (xx - x) * p["c_mu_k"].astype(x.dtype)
    xr = x + (xx - x) * p["c_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense(xk, p["c_wk"])))
    k = shard(k, "batch", None, "model")
    r = jax.nn.sigmoid(dense(xr, p["c_wr"]))
    y = r * dense(k, p["c_wv"])
    return shard(y, "batch", None, None), state._replace(x_chan=x[:, -1, :])
