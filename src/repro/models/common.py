"""Shared model components: sharding helper, norms, RoPE, losses, init."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------------------
# Ambient-mesh sharding constraint helper
# --------------------------------------------------------------------------

_ACTIVE_MESH = None
_STRATEGY = "tp"


def set_active_mesh(mesh) -> None:
    """Register the mesh used by ``shard`` constraints (None disables)."""
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_active_mesh():
    return _ACTIVE_MESH


def set_sharding_strategy(strategy: str) -> None:
    """'tp' (default) or 'fsdp' — under fsdp the batch shards over EVERY
    mesh axis (pure-DP activations) and dist.sharding fully shards the
    weights/optimizer instead (§Perf hillclimb knob)."""
    global _STRATEGY
    assert strategy in ("tp", "fsdp"), strategy
    _STRATEGY = strategy


def get_sharding_strategy() -> str:
    return _STRATEGY


def batch_axes():
    """Mesh axes the global batch is sharded over (pod- and strategy-aware)."""
    m = _ACTIVE_MESH
    if m is None:
        return None
    names = m.axis_names
    if _STRATEGY == "fsdp":
        return tuple(names)
    return ("pod", "data") if "pod" in names else ("data",)


def axis_size(name: str) -> int:
    """Size of a mesh axis on the active mesh (1 if unset/absent)."""
    m = _ACTIVE_MESH
    if m is None or name not in m.axis_names:
        return 1
    return m.shape[name]


def batch_axes_for(dim: int):
    """Largest batch-axis combination that divides ``dim`` evenly.

    Under fsdp on the multi-pod mesh the full set is 512-way but a
    256-sequence batch can only shard 256 ways — prefer dropping 'pod'
    first, then 'model', then 'data'."""
    axes = batch_axes()
    if axes is None:
        return None
    m = _ACTIVE_MESH
    candidates = [axes]
    if len(axes) >= 2:
        candidates.append(tuple(a for a in axes if a != "pod"))
        candidates.append(tuple(a for a in axes if a != "model"))
        candidates.append(tuple(a for a in axes
                                if a not in ("pod", "model")))
        candidates += [(a,) for a in axes]
    for c in candidates:
        if not c:
            continue
        total = math.prod(m.shape[a] for a in c)
        if total > 1 and dim % total == 0:
            return c
    return None


def shard(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint against the active mesh (no-op if unset).

    Axis entries may be None, a mesh axis name, a tuple of names, or the
    sentinel "batch" which expands to the pod-aware batch axes.  Entries
    whose mesh axes would not divide the dimension are dropped (GSPMD would
    pad; for activations we prefer replication over padding).  Axes that
    are *manual* in the current context (inside a partial-auto shard_map,
    e.g. the compressed-DP train step) are dropped too — the constraint
    then only talks about the still-automatic axes.
    """
    if _ACTIVE_MESH is None:
        return x
    manual = set()
    try:
        am = jax.sharding.get_abstract_mesh()
        manual = set(getattr(am, "manual_axes", ()) or ())
    except Exception:
        pass
    names = set(_ACTIVE_MESH.axis_names) - manual
    resolved = []
    used = set()
    for dim, s in zip(x.shape, spec):
        if s == "batch":
            s = batch_axes_for(dim)
        if s is None:
            resolved.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        axes = tuple(a for a in axes if a in names and a not in used)
        if not axes:
            resolved.append(None)
            continue
        total = math.prod(_ACTIVE_MESH.shape[a] for a in axes)
        if dim % total == 0:
            resolved.append(axes)
            used.update(axes)
        else:
            resolved.append(None)
    resolved += [None] * (x.ndim - len(resolved))
    if not manual:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(_ACTIVE_MESH, P(*resolved)))
    # inside a partial-auto shard_map: constrain against the context mesh
    # (which carries the Manual/Auto axis types)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(am, P(*resolved)))


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """Rotary position embedding.  x: (..., S, H, Dh), positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (..., S, half)
    ang = ang[..., None, :]                                      # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x @ w with f32 accumulation (bf16-friendly)."""
    return jax.lax.dot_general(
        x, w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTS = {"silu": jax.nn.silu, "gelu": gelu, "relu": jax.nn.relu}


# --------------------------------------------------------------------------
# Chunked cross-entropy (vocab- and sequence-sharded friendly)
# --------------------------------------------------------------------------

def chunked_softmax_xent(x: jnp.ndarray, w_out: jnp.ndarray,
                         labels: jnp.ndarray, chunk: int = 2048,
                         logit_cap: Optional[float] = None,
                         real_vocab: Optional[int] = None,
                         unroll: bool = False) -> jnp.ndarray:
    """Mean token cross entropy without materializing full (T, V) logits.

    x: (B, S, d) activations, w_out: (d, V), labels: (B, S) int32.
    Scans over sequence chunks; each chunk's logits peak at (B, chunk, V).
    ``real_vocab`` masks padded vocabulary rows out of the logsumexp.
    """
    b, s, d = x.shape
    v = w_out.shape[-1]
    chunk = min(chunk, s)
    n_chunk = -(-s // chunk)
    pad = n_chunk * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    weights = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    xc = x.reshape(b, n_chunk, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunk, chunk).transpose(1, 0, 2)
    wc = weights.reshape(b, n_chunk, chunk).transpose(1, 0, 2)

    def body(acc, inp):
        xi, li, wi = inp
        logits = dense(xi, w_out).astype(jnp.float32)
        if logit_cap is not None:
            logits = softcap(logits, logit_cap)
        if real_vocab is not None and real_vocab < v:
            logits = jnp.where(jnp.arange(v) < real_vocab, logits, -1e30)
        logits = shard(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return acc + ((lse - gold) * wi).sum(), None

    if unroll:   # costing mode (see dryrun.py)
        total = jnp.float32(0.0)
        for i in range(n_chunk):
            total, _ = body(total, (xc[i], lc[i], wc[i]))
    else:
        total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc, wc))
    return total / jnp.maximum(weights.sum(), 1.0)


# --------------------------------------------------------------------------
# Init helpers
# --------------------------------------------------------------------------

def ninit(key, shape, scale: float, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zinit(shape, dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.zeros(shape, dtype)
