"""Dense FFN variants: SwiGLU / GeGLU / plain-GELU MLP."""
from __future__ import annotations

import math

import jax

from repro.models.common import ACTS, dense, gelu, ninit, shard


def init_ffn(key, cfg, d_ff: int | None = None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    sc = 1.0 / math.sqrt(d)
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "w_in": ninit(ks[0], (d, ff), sc, cfg.param_dtype),
        "w_out": ninit(ks[1], (ff, d), 1.0 / math.sqrt(ff), cfg.param_dtype),
    }
    if gated:
        p["w_gate"] = ninit(ks[2], (d, ff), sc, cfg.param_dtype)
    return p


def apply_ffn(params, x, cfg):
    """x: (B,S,d) -> (B,S,d)."""
    h = dense(x, params["w_in"])
    h = shard(h, "batch", None, "model")
    if cfg.act == "swiglu":
        h = jax.nn.silu(dense(x, params["w_gate"])) * h
    elif cfg.act == "geglu":
        h = gelu(dense(x, params["w_gate"])) * h
    else:
        h = ACTS.get(cfg.act, gelu)(h)
    y = dense(h, params["w_out"])
    return shard(y, "batch", None, None)
