"""Decoder blocks: one init/apply pair per layer kind.

Kinds: 'global' / 'local' (attention), 'recurrent' (RG-LRU mixer + FFN),
'rwkv' (time-mix + channel-mix).  All blocks are pre-norm residual.  MoE
configs replace the dense FFN with routed experts (plus Arctic's parallel
dense-residual FFN when cfg.dense_residual).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (apply_attention, cache_spec,
                                    init_attention, init_cache)
from repro.models.common import rms_norm, shard
from repro.models.ffn import apply_ffn, init_ffn
from repro.models.moe import apply_moe, apply_moe_shard_map, init_moe
from repro.models.rglru import (apply_rglru, init_rglru,
                                init_rglru_state, rglru_state_spec)
from repro.models.rwkv6 import (channel_mix, init_rwkv,
                                init_rwkv_state, rwkv_state_spec, time_mix)


def init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype)}
    if kind == "rwkv":
        p["rwkv"] = init_rwkv(ks[0], cfg)
        p["ln2"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
        return p
    if kind == "recurrent":
        p["mixer"] = init_rglru(ks[0], cfg)
    else:  # global / local attention
        p["attn"] = init_attention(ks[0], cfg, kind)
    p["ln2"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    if cfg.num_experts > 0:
        p["moe"] = init_moe(ks[1], cfg)
        if cfg.dense_residual:
            p["dense_ffn"] = init_ffn(ks[2], cfg,
                                      d_ff=cfg.moe_dense_ff or cfg.d_ff)
    else:
        p["ffn"] = init_ffn(ks[1], cfg)
    return p


def block_cache(cfg, kind: str, batch: int, max_len: int, spec: bool = False):
    """Decode-state structure for one block of the given kind."""
    if kind == "rwkv":
        return rwkv_state_spec(cfg, batch) if spec else init_rwkv_state(cfg, batch)
    if kind == "recurrent":
        return rglru_state_spec(cfg, batch) if spec else init_rglru_state(cfg, batch)
    return (cache_spec(cfg, kind, batch, max_len) if spec
            else init_cache(cfg, kind, batch, max_len))


def apply_block(params, x, cfg, kind: str, cache: Optional[Any] = None,
                pos_offset: jnp.ndarray | int = 0
                ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (x', cache', aux_loss)."""
    aux = jnp.float32(0.0)
    if kind == "rwkv":
        h, cache = time_mix(params["rwkv"], rms_norm(x, params["ln1"],
                                                     cfg.norm_eps), cfg, cache)
        x = x + h
        h, cache = channel_mix(params["rwkv"],
                               rms_norm(x, params["ln2"], cfg.norm_eps),
                               cfg, cache)
        x = x + h
        return shard(x, "batch", None, None), cache, aux

    if kind == "recurrent":
        h, cache = apply_rglru(params["mixer"],
                               rms_norm(x, params["ln1"], cfg.norm_eps),
                               cfg, cache)
    else:
        h, cache = apply_attention(params["attn"],
                                   rms_norm(x, params["ln1"], cfg.norm_eps),
                                   cfg, kind, cache=cache,
                                   pos_offset=pos_offset)
    x = x + h

    y = rms_norm(x, params["ln2"], cfg.norm_eps)
    if cfg.num_experts > 0:
        moe_fn = (apply_moe_shard_map if cfg.moe_impl == "shard_map"
                  else apply_moe)
        m, aux = moe_fn(params["moe"], y, cfg)
        if cfg.dense_residual:
            m = m + apply_ffn(params["dense_ffn"], y, cfg)
        x = x + m
    else:
        x = x + apply_ffn(params["ffn"], y, cfg)
    return shard(x, "batch", None, None), cache, aux
