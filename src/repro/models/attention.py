"""Attention: GQA/MQA, RoPE, chunked-causal (flash-style), local windows,
logit softcap, qk-norm, and decode with full or ring-buffer KV caches."""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (axis_size, dense, ninit, rms_norm, rope,
                                 shard, softcap)


class KVCache(NamedTuple):
    """Decode KV cache.

    Positions come in two layouts:
      * shared: ``pos (S_cache,)``, ``next_pos ()`` — every batch row is at
        the same decode position (the train/prefill/greedy-serve path);
      * per-row: ``pos (B, S_cache)``, ``next_pos (B,)`` — rows advance
        independently (continuous-batching serve, where each slot holds a
        different request).  ``rowwise_cache`` converts shared -> per-row.
    Masking is by absolute position in both layouts, so the attention math
    is identical; only the write/mask indexing differs.
    """
    k: jnp.ndarray       # (B, S_cache, Hkv, Dh)
    v: jnp.ndarray       # (B, S_cache, Hkv, Dh)
    pos: jnp.ndarray     # (S_cache,) or (B, S_cache) absolute pos (-1 = empty)
    next_pos: jnp.ndarray  # () or (B,) int32 next absolute position


def init_attention(key, cfg, kind: str):
    """Projections are stored FUSED 2-D ((d, H*Dh) / (H*Dh, d)) so the
    feature dim shards over 'model' for any head count (odd head counts
    like 36 or 10 cannot shard the head dim over 16; the fused feature dim
    is always a multiple of the axis) — megatron column/row parallel."""
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    p = {
        "w_q": ninit(ks[0], (d, hq * dh), sc, cfg.param_dtype),
        "w_k": ninit(ks[1], (d, hkv * dh), sc, cfg.param_dtype),
        "w_v": ninit(ks[2], (d, hkv * dh), sc, cfg.param_dtype),
        "w_o": ninit(ks[3], (hq * dh, d), 1.0 / math.sqrt(hq * dh),
                     cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((dh,), cfg.param_dtype)
        p["k_scale"] = jnp.zeros((dh,), cfg.param_dtype)
    return p


def _theta(cfg, kind: str) -> float:
    if kind == "local" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def _window(cfg, kind: str) -> Optional[int]:
    return cfg.window_size if kind == "local" else None


def _gqa_scores(q, k, attn_cap):
    """q: (B,Sq,Hkv,G,Dh), k: (B,Skv,Hkv,Dh) -> (B,Hkv,G,Sq,Skv) f32."""
    s = jnp.einsum("bqhgd,bshd->bhgqs", q, k,
                   preferred_element_type=jnp.float32)
    return softcap(s, attn_cap)


def _gqa_out(probs, v):
    """probs: (B,Hkv,G,Sq,Skv) f32, v: (B,Skv,Hkv,Dh) -> (B,Sq,Hkv,G,Dh)."""
    return jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(v.dtype)


def _chunk_attend(q, k, v, q_lo, kv_lo, *, window, attn_cap, scale,
                  heads_sharded):
    """Attend one q chunk to one kv span with causal(+window) masking.

    Scores are the big intermediate: sharded over 'model' on the kv-head
    dim when the head count divides the axis, otherwise on the q-chunk dim
    (sequence/context parallelism — the fallback for GQA archs with few kv
    heads).
    """
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    qpos = q_lo + jnp.arange(sq)
    kpos = kv_lo + jnp.arange(skv)
    mask = qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = _gqa_scores(q * scale, k, attn_cap)      # (B,Hkv,G,Sq,Skv)
    if heads_sharded:
        s = shard(s, "batch", "model", None, None, None)
    else:
        s = shard(s, "batch", None, None, "model", None)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v)


def attend_train(q, k, v, *, window: Optional[int], attn_cap: Optional[float],
                 chunk: int = 1024) -> jnp.ndarray:
    """Causal (optionally windowed) attention over a full sequence.

    Statically chunked over queries; each chunk only reads the kv span it
    can see (so local layers do ~(window/S) of the full-attention FLOPs).
    q: (B,S,Hq,Dh) -> (B,S,Hq,Dh)
    """
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    chunk = min(chunk, s)
    n = -(-s // chunk)
    heads_sharded = hkv % axis_size("model") == 0
    outs = []
    for ci in range(n):
        q_lo, q_hi = ci * chunk, min((ci + 1) * chunk, s)
        kv_lo = 0 if window is None else max(0, q_hi - window - (chunk - 1))
        kv_lo = (kv_lo // chunk) * chunk
        kv_hi = q_hi
        qc = qg[:, q_lo:q_hi]
        kc = k[:, kv_lo:kv_hi]
        vc = v[:, kv_lo:kv_hi]
        outs.append(_chunk_attend(qc, kc, vc, q_lo, kv_lo, window=window,
                                  attn_cap=attn_cap, scale=scale,
                                  heads_sharded=heads_sharded))
    return jnp.concatenate(outs, axis=1).reshape(b, s, hq, dh)


def attend_decode(q, cache: KVCache, *, window: Optional[int],
                  attn_cap: Optional[float]) -> jnp.ndarray:
    """One-token attention against a (possibly ring) KV cache.

    q: (B,1,Hq,Dh) -> (B,1,Hq,Dh).  Masking is by absolute positions stored
    in the cache, so ring buffers need no unrotation.
    """
    b, _, hq, dh = q.shape
    hkv = cache.k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, dh) * (1.0 / math.sqrt(dh))
    s = _gqa_scores(qg, cache.k, attn_cap)          # (B,Hkv,G,1,Skv)
    cur = cache.next_pos - 1                         # position of this token
    if cache.pos.ndim == 2:                          # per-row positions
        cur = cur[:, None]                           # (B, 1)
    valid = cache.pos >= 0
    valid &= cache.pos <= cur
    if window is not None:
        valid &= (cur - cache.pos) < window
    if cache.pos.ndim == 2:
        s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    else:
        s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, cache.v).reshape(b, 1, hq, dh)


def init_cache(cfg, kind: str, batch: int, max_len: int) -> KVCache:
    """Allocate an empty cache; local layers use a window-sized ring."""
    w = _window(cfg, kind)
    s_cache = min(max_len, w) if w is not None else max_len
    shape = (batch, s_cache, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, cfg.activation_dtype),
        v=jnp.zeros(shape, cfg.activation_dtype),
        pos=jnp.full((s_cache,), -1, jnp.int32),
        next_pos=jnp.zeros((), jnp.int32),
    )


def cache_spec(cfg, kind: str, batch: int, max_len: int) -> KVCache:
    """ShapeDtypeStruct version of init_cache (dry-run, no allocation)."""
    w = _window(cfg, kind)
    s_cache = min(max_len, w) if w is not None else max_len
    shape = (batch, s_cache, cfg.num_kv_heads, cfg.head_dim)
    sds = jax.ShapeDtypeStruct
    return KVCache(k=sds(shape, cfg.activation_dtype),
                   v=sds(shape, cfg.activation_dtype),
                   pos=sds((s_cache,), jnp.int32),
                   next_pos=sds((), jnp.int32))


def _cache_write(cache: KVCache, k_new, v_new) -> KVCache:
    """Append one token (B,1,Hkv,Dh) at next_pos (ring semantics)."""
    s_cache = cache.k.shape[1]
    slot = cache.next_pos % s_cache
    if cache.next_pos.ndim == 1:                     # per-row positions
        rows = jnp.arange(cache.k.shape[0])
        k = cache.k.at[rows, slot].set(k_new[:, 0])
        v = cache.v.at[rows, slot].set(v_new[:, 0])
        pos = cache.pos.at[rows, slot].set(cache.next_pos)
        return KVCache(k, v, pos, cache.next_pos + 1)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache.pos, cache.next_pos[None], slot, axis=0)
    return KVCache(k, v, pos, cache.next_pos + 1)


def rowwise_cache(cache: KVCache, stacked: bool = False) -> KVCache:
    """Shared-position cache -> per-row positions (idempotent).

    ``stacked=True`` handles scanned-group caches, whose leaves carry a
    leading (n_groups,) axis (k: (G,B,S,Hkv,Dh), pos: (G,S), next_pos (G,)).
    """
    batch = cache.k.shape[1 if stacked else 0]
    if stacked:
        if cache.pos.ndim == 3:
            return cache
        g = cache.pos.shape[0]
        pos = jnp.broadcast_to(cache.pos[:, None], (g, batch)
                               + cache.pos.shape[1:])
        nxt = jnp.broadcast_to(cache.next_pos[:, None], (g, batch))
    else:
        if cache.pos.ndim == 2:
            return cache
        pos = jnp.broadcast_to(cache.pos[None], (batch,) + cache.pos.shape)
        nxt = jnp.broadcast_to(cache.next_pos[None], (batch,))
    return KVCache(cache.k, cache.v, pos, nxt)


def _prefill_cache(cfg, kind, k, v, s: int) -> KVCache:
    """Build a cache from full-sequence K/V after prefill."""
    w = _window(cfg, kind)
    if w is not None and k.shape[1] > w:
        # keep the last `w` entries; slot = pos % w keeps ring semantics
        start = s - w
        ks, vs = k[:, start:], v[:, start:]
        pos_tail = jnp.arange(start, s)
        slots = pos_tail % w
        order = jnp.argsort(slots)
        return KVCache(ks[:, order], vs[:, order], pos_tail[order],
                       jnp.int32(s))
    s_cache = k.shape[1]
    return KVCache(k, v,
                   jnp.arange(s_cache, dtype=jnp.int32),
                   jnp.int32(s))


def apply_attention(params, x, cfg, kind: str,
                    cache: Optional[KVCache] = None,
                    pos_offset: jnp.ndarray | int = 0
                    ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Full attention sub-block: proj -> rope -> attend -> out-proj.

    Train/prefill: x is (B,S,d), cache None -> returns (y, prefill cache).
    Decode: x is (B,1,d), cache given -> returns (y, updated cache).
    """
    b, s, d = x.shape
    theta = _theta(cfg, kind)
    w = _window(cfg, kind)

    q = dense(x, params["w_q"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = dense(x, params["w_k"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = dense(x, params["w_v"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    # heads on 'model' when divisible, else q on the sequence dim
    # (context parallel); k/v stay replicated over 'model' for local reads.
    if cfg.num_heads % axis_size("model") == 0:
        q = shard(q, "batch", None, "model", None)
    else:
        q = shard(q, "batch", "model", None, None)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_scale"], cfg.norm_eps)

    decode = cache is not None and s == 1
    if decode:
        if cache.next_pos.ndim == 1:                 # per-row positions
            positions = cache.next_pos[:, None].astype(jnp.int32)
        else:
            positions = jnp.full((b, 1), cache.next_pos, jnp.int32)
    else:
        positions = (jnp.arange(s, dtype=jnp.int32)[None, :]
                     + jnp.asarray(pos_offset, jnp.int32))
        positions = jnp.broadcast_to(positions, (b, s))
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)

    if decode:
        new_cache = _cache_write(cache, k.astype(cache.k.dtype),
                                 v.astype(cache.v.dtype))
        out = attend_decode(q, new_cache, window=w, attn_cap=cfg.attn_softcap)
    else:
        out = attend_train(q, k, v, window=w, attn_cap=cfg.attn_softcap,
                           chunk=cfg.attn_chunk)
        new_cache = _prefill_cache(cfg, kind, k, v, s)

    out = shard(out, "batch", None, "model", None)
    y = dense(out.reshape(b, s, -1), params["w_o"])
    return shard(y, "batch", None, None), new_cache
