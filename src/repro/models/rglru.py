"""Griffin/RecurrentGemma recurrent block: conv1d + RG-LRU gated recurrence
[arXiv:2402.19427].

RG-LRU:  r_t = sigmoid(W_a x_t + b_a),  i_t = sigmoid(W_x x_t + b_x)
         log a_t = -c * softplus(Lambda) * r_t          (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses jax.lax.associative_scan over the linear recurrence
(log-depth, TPU-friendly); decode is a single fused step with carried
(h, conv window) state.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, gelu, ninit, shard

_C = 8.0
_CONV_W = 4


class RGLRUState(NamedTuple):
    h: jnp.ndarray          # (B, d_rnn) recurrence state
    conv: jnp.ndarray       # (B, CONV_W-1, d_rnn) trailing conv inputs


def init_rglru(key, cfg):
    d = cfg.d_model
    dr = cfg.rnn_width
    ks = iter(jax.random.split(key, 8))
    sc = 1.0 / math.sqrt(d)
    return {
        "w_branch": ninit(next(ks), (d, dr), sc, cfg.param_dtype),
        "w_gate_branch": ninit(next(ks), (d, dr), sc, cfg.param_dtype),
        "conv_w": ninit(next(ks), (_CONV_W, dr), 0.1, cfg.param_dtype),
        "conv_b": jnp.zeros((dr,), cfg.param_dtype),
        "w_a": ninit(next(ks), (dr, dr), 1.0 / math.sqrt(dr), cfg.param_dtype),
        "b_a": jnp.zeros((dr,), cfg.param_dtype),
        "w_x": ninit(next(ks), (dr, dr), 1.0 / math.sqrt(dr), cfg.param_dtype),
        "b_x": jnp.zeros((dr,), cfg.param_dtype),
        # Lambda init so a ~ uniform in [0.9, 0.999] (Griffin appendix)
        "lam": jnp.asarray(
            jnp.linspace(2.0, 6.0, dr), cfg.param_dtype),
        "w_out": ninit(next(ks), (dr, d), 1.0 / math.sqrt(dr), cfg.param_dtype),
    }


def init_rglru_state(cfg, batch: int) -> RGLRUState:
    dr = cfg.rnn_width
    return RGLRUState(h=jnp.zeros((batch, dr), jnp.float32),
                      conv=jnp.zeros((batch, _CONV_W - 1, dr),
                                     cfg.activation_dtype))


def rglru_state_spec(cfg, batch: int) -> RGLRUState:
    dr = cfg.rnn_width
    sds = jax.ShapeDtypeStruct
    return RGLRUState(h=sds((batch, dr), jnp.float32),
                      conv=sds((batch, _CONV_W - 1, dr),
                               cfg.activation_dtype))


def _causal_conv(p, u, prev):
    """Width-4 causal depthwise conv.  u: (B,S,dr), prev: (B,3,dr)."""
    full = jnp.concatenate([prev.astype(u.dtype), u], axis=1)
    acc = p["conv_b"].astype(u.dtype)
    s = u.shape[1]
    out = sum(full[:, i:i + s, :] * p["conv_w"][i].astype(u.dtype)
              for i in range(_CONV_W))
    return out + acc


def _rg_lru_scan(p, u):
    """Associative-scan RG-LRU over u: (B,S,dr) -> (h_seq, h_last)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(uf, p["w_a"].astype(jnp.float32))
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(dense(uf, p["w_x"].astype(jnp.float32))
                       + p["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq, h_seq = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h_seq, h_seq[:, -1, :]


def _rg_lru_step(p, u, h):
    """Single decode step.  u: (B,1,dr), h: (B,dr) -> (out, h')."""
    uf = u[:, 0, :].astype(jnp.float32)
    r = jax.nn.sigmoid(dense(uf, p["w_a"].astype(jnp.float32))
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(dense(uf, p["w_x"].astype(jnp.float32))
                       + p["b_x"].astype(jnp.float32))
    a = jnp.exp(-_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r)
    h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return h[:, None, :], h


def apply_rglru(p, x, cfg, state: Optional[RGLRUState]
                ) -> Tuple[jnp.ndarray, RGLRUState]:
    """Full Griffin recurrent mixer.  x: (B,S,d) -> (B,S,d)."""
    b, s, d = x.shape
    if state is None:
        state = init_rglru_state(cfg, b)

    u_in = dense(x, p["w_branch"])
    u_in = shard(u_in, "batch", None, "model")
    gate = gelu(dense(x, p["w_gate_branch"]))
    u = _causal_conv(p, u_in, state.conv)

    if s == 1:
        h_seq, h_last = _rg_lru_step(p, u, state.h)
    else:
        h_seq, h_last = _rg_lru_scan(p, u)

    new_conv = jnp.concatenate(
        [state.conv.astype(x.dtype), u_in], axis=1)[:, -(_CONV_W - 1):, :]
    y = dense(h_seq.astype(x.dtype) * gate, p["w_out"])
    return (shard(y, "batch", None, None),
            RGLRUState(h=h_last, conv=new_conv))
