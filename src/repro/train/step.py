"""train_step factories: baseline pjit and compressed-DP (shard_map) modes.

Baseline: jax.jit with param/batch shardings; GSPMD inserts the DP gradient
all-reduce (bf16).  Compressed: the 'data' (and 'pod') axes are made manual
with jax.shard_map(axis_names=...) while 'model' stays auto, and the DP
reduction runs through dist.collectives.compressed_psum — the paper's
quantizer on the wire (error-bounded, error-feedback).  See DESIGN.md §2.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import (WIRE_FORMATS, compressed_psum_tree,
                                    topo_compressed_psum_tree)
from repro.dist.compat import HAS_PARTIAL_AUTO, shard_map
from repro.dist.sharding import batch_axes
from repro.models import lm
from repro.train.state import TrainState


def make_loss_fn(cfg) -> Callable:
    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch)
    return loss


def make_train_step(cfg, optimizer, mesh=None, grad_compress: bool = False,
                    rel_eb: float = 1e-3,
                    topo_frac: Optional[float] = None,
                    wire_format: Optional[str] = None) -> Callable:
    """Returns step(state, batch) -> (state', metrics).

    ``topo_frac > 0`` upgrades the compressed DP reduction to the
    topology-aware collective: the per-member top ``topo_frac`` tail of
    each gradient leaf (by ``|g + err|``) rides an exact fp32 sidecar, so
    optimizer-driving extrema keep their exact values and rank order
    while the body stays ``rel_eb``-bounded.  ``None`` (default) defers
    to ``cfg.grad_topo_frac``; an explicit ``0.0`` forces the plain
    compressed psum regardless of the config.

    ``wire_format`` picks how the codes move: ``"int32"`` (full int32
    psum, accounting-only byte win) or ``"packed"`` (dist.ring bitpacked
    ppermute ring all-reduce — the compressed bytes ARE the wire).
    ``None`` defers to ``cfg.grad_wire_format``.
    """
    loss_fn = make_loss_fn(cfg)
    if topo_frac is None:
        topo_frac = getattr(cfg, "grad_topo_frac", 0.0)
    if wire_format is None:
        wire_format = getattr(cfg, "grad_wire_format", "int32")
    if wire_format not in WIRE_FORMATS:
        raise ValueError(f"unknown wire_format {wire_format!r}; "
                         f"expected one of {WIRE_FORMATS}")
    if topo_frac > 0.0 and not grad_compress:
        raise ValueError(
            "topo_frac > 0 requires grad_compress=True: the protected "
            "tail is a sidecar of the compressed collective, not of the "
            "uncompressed GSPMD all-reduce")
    if wire_format != "int32" and not grad_compress:
        raise ValueError(
            "wire_format='packed' requires grad_compress=True: only the "
            "compressed collective has codes to bitpack")

    if not grad_compress:
        def step(state: TrainState, batch):
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            params, opt_state = optimizer.update(grads, state.opt_state,
                                                 state.params)
            new = TrainState(state.step + 1, params, opt_state, state.err)
            return new, {"loss": loss}
        return step

    assert mesh is not None, "compressed-DP mode needs the mesh"
    dp_axes = batch_axes(mesh)
    # Partial-auto ('model' stays GSPMD-parallel) needs the modern
    # jax.shard_map; legacy XLA fatally asserts on it for real model
    # graphs, so there the whole step runs manual and the model-axis
    # replicas redundantly compute their DP shard (correct, DP-only).
    manual_axes = set(dp_axes) if HAS_PARTIAL_AUTO else None

    def per_shard(params, err, batch):
        # local-shard loss/grads; 'model' axis stays auto-parallel
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if topo_frac > 0.0:
            grads, err = topo_compressed_psum_tree(
                grads, dp_axes, rel_eb, topo_frac, err,
                wire_format=wire_format)
        else:
            grads, err = compressed_psum_tree(grads, dp_axes, rel_eb, err,
                                              wire_format=wire_format)
        loss = jax.lax.pmean(loss, dp_axes)
        # NOTE: err is genuinely per-DP-member but leaves through
        # out_specs=P() (check_vma=False).  On-device across steps each
        # member keeps consuming its own residual shard, so EF-SGD is
        # exact in the steady loop; a host transfer (checkpoint) collapses
        # the tree to member 0's residual, which forfeits at most one
        # step's eb-scale compensation on restore.  The alternative — a
        # replicated pmean'd residual — would double the collective
        # volume and defeat the wire win.
        return loss, grads, err

    def step(state: TrainState, batch):
        batch_specs = jax.tree.map(
            lambda x: P(dp_axes, *([None] * (x.ndim - 1))), batch)
        sharded = shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(P(), P(), batch_specs),
            out_specs=(P(), P(), P()),
            axis_names=manual_axes,
            check_vma=False,
        )
        loss, grads, err = sharded(state.params, state.err, batch)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        new = TrainState(state.step + 1, params, opt_state, err)
        return new, {"loss": loss}

    return step
