"""train_step factories: baseline pjit and compressed-DP (shard_map) modes.

Baseline: jax.jit with param/batch shardings; GSPMD inserts the DP gradient
all-reduce (bf16).  Compressed: the 'data' (and 'pod') axes are made manual
with jax.shard_map(axis_names=...) while 'model' stays auto, and the DP
reduction runs through dist.collectives.compressed_psum — the paper's
quantizer on the wire (error-bounded, error-feedback).  See DESIGN.md §2.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import compressed_psum_tree
from repro.dist.sharding import batch_axes
from repro.models import lm
from repro.train.state import TrainState


def make_loss_fn(cfg) -> Callable:
    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch)
    return loss


def make_train_step(cfg, optimizer, mesh=None, grad_compress: bool = False,
                    rel_eb: float = 1e-3) -> Callable:
    """Returns step(state, batch) -> (state', metrics)."""
    loss_fn = make_loss_fn(cfg)

    if not grad_compress:
        def step(state: TrainState, batch):
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            params, opt_state = optimizer.update(grads, state.opt_state,
                                                 state.params)
            new = TrainState(state.step + 1, params, opt_state, state.err)
            return new, {"loss": loss}
        return step

    assert mesh is not None, "compressed-DP mode needs the mesh"
    dp_axes = batch_axes(mesh)

    def per_shard(params, err, batch):
        # local-shard loss/grads; 'model' axis stays auto-parallel
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, err = compressed_psum_tree(grads, dp_axes, rel_eb, err)
        loss = jax.lax.pmean(loss, dp_axes)
        return loss, grads, err

    bspec = P(dp_axes)

    def step(state: TrainState, batch):
        batch_specs = jax.tree.map(
            lambda x: P(dp_axes, *([None] * (x.ndim - 1))), batch)
        sharded = jax.shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(P(), P(), batch_specs),
            out_specs=(P(), P(), P()),
            axis_names=set(dp_axes),
            check_vma=False,
        )
        loss, grads, err = sharded(state.params, state.err, batch)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        new = TrainState(state.step + 1, params, opt_state, err)
        return new, {"loss": loss}

    return step
