from repro.train.state import TrainState, init_state, shard_state
from repro.train.step import make_train_step, make_loss_fn
from repro.train.loop import train_loop, LoopReport, PreemptionError

__all__ = ["TrainState", "init_state", "shard_state", "make_train_step",
           "make_loss_fn", "train_loop", "LoopReport", "PreemptionError"]
