"""Training state container."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: AdamWState
    err: Any               # gradient-compression error feedback (or None-like)


def init_state(params, optimizer, grad_compress: bool) -> TrainState:
    err = (jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
           if grad_compress else None)
    return TrainState(jnp.zeros((), jnp.int32), params,
                      optimizer.init(params), err)


def shard_state(state: TrainState, cfg, mesh) -> TrainState:
    """Lay a TrainState out on ``mesh`` with the model's sharding rules.

    Params, fp32 master copies, both Adam moments and the error-feedback
    tree all follow ``dist.sharding.param_shardings`` (they are
    param-shaped); scalars replicate.  This is the optimizer-state
    resharding half of the elastic restart path: after
    ``dist.elastic.rebuild_mesh`` shrinks the mesh, the restored state is
    pushed through here (or through the checkpoint manifest's saved
    specs) to land on the surviving devices.
    """
    from repro.dist.sharding import param_shardings, replicated

    p_sh = param_shardings(jax.eval_shape(lambda: state.params), cfg, mesh)
    rep = replicated(jnp.zeros(()), mesh)

    def put(tree, shardings):
        if tree is None:
            return None
        return jax.tree.map(jax.device_put, tree, shardings)

    opt = state.opt_state._replace(
        step=jax.device_put(state.opt_state.step, rep),
        master=put(state.opt_state.master, p_sh),
        m=put(state.opt_state.m, p_sh),
        v=put(state.opt_state.v, p_sh))
    return TrainState(jax.device_put(state.step, rep),
                      put(state.params, p_sh), opt,
                      put(state.err, p_sh))
