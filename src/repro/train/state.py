"""Training state container."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: AdamWState
    err: Any               # gradient-compression error feedback (or None-like)


def init_state(params, optimizer, grad_compress: bool) -> TrainState:
    err = (jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
           if grad_compress else None)
    return TrainState(jnp.zeros((), jnp.int32), params,
                      optimizer.init(params), err)
