"""Fault-tolerant training loop: checkpoint/restart, straggler detection,
preemption simulation hooks.

Designed for 1000+-node operation:
  * checkpoint every N steps through ckpt.manager (atomic + hashed), restore
    on start — a preempted/crashed job resumes exactly;
  * straggler mitigation: per-step wall time tracked with an EWMA; a step
    slower than ``straggler_z`` sigmas triggers the mitigation hook (on a
    real cluster: reshard/evict; here: recorded event + callback);
  * elasticity: on a world-size change the loop rebuilds the data iterator
    sharding through dist.elastic (device loss handled between steps).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.ckpt import manager as ckpt
from repro.train.state import TrainState


@dataclass
class LoopReport:
    steps_run: int = 0
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    straggler_events: List[int] = field(default_factory=list)
    restored_from: Optional[int] = None
    checkpoints: List[int] = field(default_factory=list)


class PreemptionError(RuntimeError):
    """Raised by the preemption simulator to model a node loss."""


def train_loop(state: TrainState, step_fn: Callable, data_iter,
               num_steps: int, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 50, log_every: int = 10,
               straggler_z: float = 4.0,
               straggler_hook: Optional[Callable[[int, float], None]] = None,
               preempt_at: Optional[int] = None,
               ckpt_compress: Optional[str] = None,
               log: Callable[[str], None] = print) -> (TrainState, LoopReport):
    """Run ``num_steps`` with full fault-tolerance plumbing."""
    report = LoopReport()

    if ckpt_dir is not None:
        restored = ckpt.restore(ckpt_dir, state)
        if restored is not None:
            state, at = restored
            report.restored_from = at
            log(f"[loop] restored checkpoint at step {at}")

    compiled = jax.jit(step_fn, donate_argnums=(0,))
    ewma_t, ewma_var = None, 0.0

    start = int(state.step)
    for i in range(start, num_steps):
        if preempt_at is not None and i == preempt_at:
            raise PreemptionError(f"simulated preemption at step {i}")
        batch = next(data_iter)
        t0 = time.perf_counter()
        state, metrics = compiled(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0

        # straggler detection (EWMA z-score on step time)
        if ewma_t is None:
            ewma_t = dt
        else:
            sigma = max(ewma_var, 1e-12) ** 0.5
            if dt > ewma_t + straggler_z * sigma and i > start + 5:
                report.straggler_events.append(i)
                if straggler_hook is not None:
                    straggler_hook(i, dt)
                log(f"[loop] straggler at step {i}: {dt * 1e3:.1f} ms "
                    f"(ewma {ewma_t * 1e3:.1f} ms) — mitigation hook fired")
            ewma_t = 0.9 * ewma_t + 0.1 * dt
            ewma_var = 0.9 * ewma_var + 0.1 * (dt - ewma_t) ** 2

        loss = float(metrics["loss"])
        report.losses.append(loss)
        report.step_times.append(dt)
        report.steps_run += 1
        if i % log_every == 0:
            log(f"[loop] step {i} loss {loss:.4f} ({dt * 1e3:.1f} ms)")

        if ckpt_dir is not None and (i + 1) % ckpt_every == 0:
            path = ckpt.save(state, i + 1, ckpt_dir, compress=ckpt_compress)
            ckpt.prune(ckpt_dir)
            report.checkpoints.append(i + 1)
            log(f"[loop] checkpoint -> {path}")

    return state, report
