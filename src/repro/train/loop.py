"""Fault-tolerant training loop: checkpoint/restart, straggler detection,
mid-run elastic recovery, preemption simulation hooks.

Designed for 1000+-node operation:
  * checkpoint every N steps — either through the legacy v1 module API
    (``ckpt_dir``) or through a v2 ``ckpt.CheckpointManager``
    (``ckpt_manager``: sharded blobs, szp/toposzp leaf compression, async
    background writes, coordinated multi-process commit) — restore on
    start so a preempted job resumes;
  * elasticity: when the checkpoint was written on a different mesh shape
    than the current world (device loss / regrowth), the loop rebuilds the
    largest valid mesh from the surviving devices via
    ``dist.elastic.rebuild_mesh`` and the manager reassembles + reshards
    every leaf onto it (saved PartitionSpecs adapted to the new mesh);
  * **mid-run** elasticity (``max_recoveries > 0``): a
    ``dist.elastic.DeviceLoss`` raised during a step — by the fault
    injector (``repro.faults``, site ``loop.step``) or a watchdog
    translating a hardware event — rolls the loop back to the async
    writer's last *committed* checkpoint, rebuilds the largest valid mesh
    from the survivors, reshards the restored state, re-jits the step
    (``rebuild_step`` builds a new step_fn against the new mesh) and
    keeps training — graceful degradation instead of a full restart.
    Counters: ``loop.recoveries``; per-event detail in
    ``LoopReport.recoveries``;
  * checkpoint accounting is reconciled against the manager's commit
    ledger: a checkpoint enters ``report.checkpoints`` only once its
    write actually COMMITTED; failed background writes land in
    ``report.failed_checkpoints`` instead of leaving phantom entries;
  * straggler mitigation: per-step wall time tracked with an EWMA; a step
    slower than ``straggler_z`` sigmas triggers the mitigation hook (on a
    real cluster: reshard/evict; here: recorded event + callback).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro import faults, obs
from repro.ckpt import manager as ckpt
from repro.ckpt.async_writer import AsyncWriteError
from repro.dist.elastic import DeviceLoss
from repro.train.state import TrainState


@dataclass
class LoopReport:
    steps_run: int = 0
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    straggler_events: List[int] = field(default_factory=list)
    restored_from: Optional[int] = None
    checkpoints: List[int] = field(default_factory=list)
    failed_checkpoints: List[int] = field(default_factory=list)
    resharded: bool = False                      # elastic restore happened
    restore_mesh: Optional[Dict[str, int]] = None  # mesh restored onto
    saved_mesh: Optional[Dict[str, int]] = None    # mesh the ckpt was on
    recoveries: List[Dict[str, Any]] = field(default_factory=list)


class PreemptionError(RuntimeError):
    """Raised by the preemption simulator to model a node loss."""


def _elastic_restore(manager, state, mesh, model_parallel, devices, report,
                     log):
    """Restore through the v2 manager, rebuilding the mesh on a world-size
    change (the dist.elastic wiring of the ROADMAP's elastic item)."""
    from repro.dist.elastic import mesh_shape_dict, rebuild_mesh

    saved = manager.peek_mesh()
    restore_mesh = mesh
    if saved is not None:
        cur = mesh_shape_dict(mesh) if mesh is not None else None
        if cur != saved:
            # World-size change (or the caller didn't rebuild a mesh):
            # re-lay the checkpoint out on the largest valid mesh the
            # surviving devices support.
            if restore_mesh is None:
                devs = devices if devices is not None else jax.devices()
                restore_mesh = rebuild_mesh(devs, model_parallel)
            report.resharded = True
            report.restore_mesh = mesh_shape_dict(restore_mesh)
            log(f"[loop] mesh changed {saved} -> {report.restore_mesh}; "
                f"resharding the restored state")
    res = manager.restore(state, mesh=restore_mesh)
    if res is None:
        report.resharded = False
        report.restore_mesh = None
        return state
    report.restored_from = res.step
    report.saved_mesh = res.saved_mesh
    log(f"[loop] restored checkpoint at step {res.step}"
        + (" (resharded)" if report.resharded else ""))
    return res.tree


def _recover(exc: DeviceLoss, at_step: int, manager, state, step_fn,
             rebuild_step, model_parallel, devices, report, log
             ) -> Tuple[Any, int, Callable, Any]:
    """Mid-run elastic recovery: roll back to the last COMMITTED
    checkpoint, rebuild the largest valid mesh from the survivors,
    reshard, re-jit.  Returns (state, resume_step, compiled, mesh).

    Raises the original ``exc`` when there is nothing committed to roll
    back to — losing devices before the first checkpoint is a restart,
    not a recovery."""
    from repro.dist.elastic import mesh_shape_dict, rebuild_mesh

    t0 = time.perf_counter()
    obs.counter_add("loop.recoveries", 1)
    try:
        manager.wait()    # flush/surface the in-flight write first
    except Exception as e:
        # a failed background write just means the last COMMITTED
        # checkpoint is older; the rollback below handles it
        log(f"[loop] in-flight checkpoint failed during recovery: {e}")
    world = devices if devices is not None else jax.devices()
    if exc.survivors is not None:
        survivors = list(exc.survivors)
    elif exc.keep is not None:
        survivors = list(world)[: exc.keep]
    else:
        survivors = list(world)   # soft restart: same devices
    if not survivors:
        raise exc
    new_mesh = rebuild_mesh(survivors, model_parallel)
    res = manager.restore(state, mesh=new_mesh)
    if res is None:
        log(f"[loop] device loss at step {at_step} with no committed "
            f"checkpoint to roll back to — giving up")
        raise exc
    fn = rebuild_step(new_mesh) if rebuild_step is not None else step_fn
    compiled = jax.jit(fn, donate_argnums=(0,))
    dt = time.perf_counter() - t0
    event = {"step": at_step, "reason": str(exc),
             "restored_from": res.step,
             "mesh": mesh_shape_dict(new_mesh),
             "devices": len(survivors), "recovery_s": dt}
    report.recoveries.append(event)
    obs.observe("loop.recovery_s", dt)
    log(f"[loop] recovered from device loss at step {at_step}: rolled "
        f"back to step {res.step}, resharded onto {event['mesh']} "
        f"({len(survivors)} devices, {dt * 1e3:.0f} ms)")
    return res.tree, int(res.step), compiled, new_mesh


def train_loop(state: TrainState, step_fn: Callable, data_iter,
               num_steps: int, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 50, log_every: int = 10,
               straggler_z: float = 4.0,
               straggler_hook: Optional[Callable[[int, float], None]] = None,
               preempt_at: Optional[int] = None,
               ckpt_compress: Optional[str] = None,
               ckpt_manager: Optional[ckpt.CheckpointManager] = None,
               mesh=None, model_parallel: int = 1, devices=None,
               max_recoveries: int = 0,
               rebuild_step: Optional[Callable] = None,
               log: Callable[[str], None] = print
               ) -> Tuple[TrainState, LoopReport]:
    """Run ``num_steps`` with full fault-tolerance plumbing.

    ``ckpt_manager`` (v2) supersedes ``ckpt_dir`` (v1) when both are
    given.  ``mesh``/``model_parallel``/``devices`` feed the elastic
    restore: a checkpoint saved on a different mesh shape is resharded
    onto ``mesh`` or, when no mesh is passed, onto
    ``dist.elastic.rebuild_mesh(devices or jax.devices(), model_parallel)``.

    ``max_recoveries`` bounds how many mid-run ``DeviceLoss`` events the
    loop absorbs by rolling back to the last committed checkpoint and
    rebuilding the mesh (0 = re-raise, the pre-elastic behavior);
    ``rebuild_step`` is called with the rebuilt mesh to produce a fresh
    step_fn (shard_map-based steps close over the mesh and must be
    rebuilt; pure jit steps may leave it None).
    """
    report = LoopReport()

    if ckpt_manager is not None:
        state = _elastic_restore(ckpt_manager, state, mesh, model_parallel,
                                 devices, report, log)
    elif ckpt_dir is not None:
        restored = ckpt.restore(ckpt_dir, state, log=log)
        if restored is not None:
            state, at = restored
            report.restored_from = at
            log(f"[loop] restored checkpoint at step {at}")

    compiled = jax.jit(step_fn, donate_argnums=(0,))
    ewma_t, ewma_var = None, 0.0
    recoveries_left = max_recoveries
    submitted: List[int] = []

    start = int(state.step)
    i = start
    while i < num_steps:
        if preempt_at is not None and i == preempt_at:
            raise PreemptionError(f"simulated preemption at step {i}")
        try:
            faults.fire("loop.step", step=i)
            batch = next(data_iter)
            t0 = time.perf_counter()
            state, metrics = compiled(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
        except DeviceLoss as e:
            if recoveries_left <= 0 or ckpt_manager is None:
                raise
            recoveries_left -= 1
            state, i, compiled, mesh = _recover(
                e, i, ckpt_manager, state, step_fn, rebuild_step,
                model_parallel, devices, report, log)
            start = min(start, i)
            ewma_t, ewma_var = None, 0.0   # step time changed regime
            continue

        # straggler detection (EWMA z-score on step time)
        if ewma_t is None:
            ewma_t = dt
        else:
            sigma = max(ewma_var, 1e-12) ** 0.5
            if dt > ewma_t + straggler_z * sigma and i > start + 5:
                report.straggler_events.append(i)
                if straggler_hook is not None:
                    straggler_hook(i, dt)
                log(f"[loop] straggler at step {i}: {dt * 1e3:.1f} ms "
                    f"(ewma {ewma_t * 1e3:.1f} ms) — mitigation hook fired")
            ewma_t = 0.9 * ewma_t + 0.1 * dt
            ewma_var = 0.9 * ewma_var + 0.1 * (dt - ewma_t) ** 2

        loss = float(metrics["loss"])
        report.losses.append(loss)
        report.step_times.append(dt)
        report.steps_run += 1
        obs.counter_add("train.steps", 1)
        obs.observe("train.step_time_s", dt)
        if i % log_every == 0:
            log(f"[loop] step {i} loss {loss:.4f} ({dt * 1e3:.1f} ms)")
            if obs.enabled():
                # pull-style snapshot of the hot-path registries; reading
                # it costs host dict walks only, never a device transfer
                log("[obs] " + obs.summary_line(
                    ("train.", "ckpt.", "loop.", "ring.", "collectives.",
                     "szp.", "toposzp.")))

        if (i + 1) % ckpt_every == 0:
            if ckpt_manager is not None:
                # async mode: pays only the device->host snapshot here
                # (plus a barrier iff the previous write is in flight).
                # A checkpoint is RECORDED only once its write commits —
                # see the reconcile against the manager's ledger below.
                try:
                    ckpt_manager.save(state, i + 1)
                except AsyncWriteError as e:
                    # the PREVIOUS write failed at this submit's barrier;
                    # the slot is free now, so resubmit this step
                    log(f"[loop] background checkpoint failed: {e}")
                    ckpt_manager.save(state, i + 1)
                submitted.append(i + 1)
                log(f"[loop] checkpoint @ step {i + 1} "
                    f"({'async' if ckpt_manager.async_write else 'sync'})")
            elif ckpt_dir is not None:
                path = ckpt.save(state, i + 1, ckpt_dir,
                                 compress=ckpt_compress)
                ckpt.prune(ckpt_dir)
                report.checkpoints.append(i + 1)
                log(f"[loop] checkpoint -> {path}")
        i += 1

    if ckpt_manager is not None:
        try:
            ckpt_manager.wait()   # commit the trailing async write
        except AsyncWriteError as e:
            log(f"[loop] trailing checkpoint failed: {e}")
        # Reconcile against the manager's commit ledger: only steps whose
        # write actually committed count; failures are reported, not
        # silently dropped (nor left as phantom checkpoints).
        committed = set(ckpt_manager.committed_steps)
        failed = dict(ckpt_manager.failed_steps)
        report.checkpoints = sorted(s for s in set(submitted)
                                    if s in committed)
        report.failed_checkpoints = sorted(s for s in set(submitted)
                                           if s in failed)
    return state, report
