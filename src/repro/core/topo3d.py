"""TopoSZp-3D — beyond-paper extension (the paper's stated future work).

Generalizes the pipeline to 3-D scalar fields with the 6-neighborhood:
  minima/maxima: all existing axis neighbors strictly higher/lower;
  saddle: interior point where each axis pair lies on one strict side and
  the axes disagree (the direct generalization of the 2-D definition).

Reuses the SZp substrate (QZ + B/LZ + BE) on the flattened field, the 2-bit
label map, the sparse CP-first rank stream, and the delta-ULP extrema
stencils with FP/FT suppression.  Saddle restoration is extrema-free in 3-D
v1 (no RBF): suppression still guarantees FP = FT = 0 and the 2-eps bound.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.quantize import dequantize, quantize
from repro.core.relative_order import compute_ranks
from repro.core.szp import (DEFAULT_BLOCK, compress_codes,
                            decompress_codes)
from repro.core.toposzp import (TopoSZpCompressed, _cp_first_dest,
                                rank_stream_bytes)
from repro.utils import ulp_step

REGULAR, MINIMA, SADDLE, MAXIMA = 0, 1, 2, 3
_AXES = (0, 1, 2)


def _axis_neighbors(f: jnp.ndarray, axis: int):
    """(prev, next, has_prev, has_next) along one axis (edge-replicated)."""
    n = f.shape[axis]
    pad = [(0, 0)] * 3
    pad[axis] = (1, 1)
    p = jnp.pad(f, pad, mode="edge")
    sl_prev = [slice(None)] * 3
    sl_prev[axis] = slice(0, n)
    sl_next = [slice(None)] * 3
    sl_next[axis] = slice(2, n + 2)
    idx = jnp.arange(n)
    shape = [1, 1, 1]
    shape[axis] = n
    ii = idx.reshape(shape)
    has_prev = jnp.broadcast_to(ii > 0, f.shape)
    has_next = jnp.broadcast_to(ii < n - 1, f.shape)
    return p[tuple(sl_prev)], p[tuple(sl_next)], has_prev, has_next


def classify3d(field: jnp.ndarray) -> jnp.ndarray:
    """6-neighbor label map for a 3-D field -> int32 {0,1,2,3}."""
    f = field.astype(jnp.float32)
    all_hi = jnp.ones(f.shape, bool)
    all_lo = jnp.ones(f.shape, bool)
    interior = jnp.ones(f.shape, bool)
    pair_hi, pair_lo = [], []
    for ax in _AXES:
        pv, nx, hp, hn = _axis_neighbors(f, ax)
        all_hi &= jnp.where(hp, pv > f, True) & jnp.where(hn, nx > f, True)
        all_lo &= jnp.where(hp, pv < f, True) & jnp.where(hn, nx < f, True)
        interior &= hp & hn
        pair_hi.append((pv > f) & (nx > f))
        pair_lo.append((pv < f) & (nx < f))

    # saddle: every axis pair strictly one-sided, and axes disagree
    one_sided = ((pair_hi[0] | pair_lo[0]) & (pair_hi[1] | pair_lo[1])
                 & (pair_hi[2] | pair_lo[2]))
    all_same_hi = pair_hi[0] & pair_hi[1] & pair_hi[2]
    all_same_lo = pair_lo[0] & pair_lo[1] & pair_lo[2]
    is_saddle = interior & one_sided & ~all_same_hi & ~all_same_lo

    lab = jnp.where(all_lo, MAXIMA, REGULAR)
    lab = jnp.where(is_saddle, SADDLE, lab)
    lab = jnp.where(all_hi, MINIMA, lab)
    return lab.astype(jnp.int32)


def _neighbor_min_max3d(f: jnp.ndarray):
    big = jnp.float32(jnp.inf)
    nmin = jnp.full(f.shape, big)
    nmax = jnp.full(f.shape, -big)
    for ax in _AXES:
        pv, nx, hp, hn = _axis_neighbors(f, ax)
        nmin = jnp.minimum(nmin, jnp.minimum(jnp.where(hp, pv, big),
                                             jnp.where(hn, nx, big)))
        nmax = jnp.maximum(nmax, jnp.maximum(jnp.where(hp, pv, -big),
                                             jnp.where(hn, nx, -big)))
    return nmin, nmax


def _dilate3d(mask: jnp.ndarray) -> jnp.ndarray:
    out = mask
    for ax in _AXES:
        pv, nx, hp, hn = _axis_neighbors(mask, ax)
        out = out | (pv & hp) | (nx & hn)
    return out


@functools.partial(jax.jit, static_argnames=("block",))
def toposzp3d_compress(field: jnp.ndarray, eb: float,
                       block: int = DEFAULT_BLOCK) -> TopoSZpCompressed:
    field = field.astype(jnp.float32)
    codes = quantize(field, eb)
    labels = classify3d(field)
    ranks = compute_ranks(field.reshape(1, -1), labels.reshape(1, -1),
                          codes.reshape(1, -1)).reshape(field.shape)

    szp_parts = compress_codes(codes.reshape(-1), block=block)
    labels_flat = labels.reshape(-1)
    labels2b = bitpack.pack_2bit(labels_flat)
    n_cp = (labels_flat != 0).sum().astype(jnp.int32)
    dest = _cp_first_dest(labels_flat)
    ranks_sorted = jnp.zeros(labels_flat.shape[0], jnp.int32).at[dest].set(
        ranks.reshape(-1), unique_indices=True)
    rank_parts = compress_codes(ranks_sorted, block=block)
    nbytes = (szp_parts.nbytes + labels2b.shape[0]
              + rank_stream_bytes(n_cp, rank_parts.payload_nbytes, block))
    return TopoSZpCompressed(szp_parts, labels2b, rank_parts, n_cp,
                             nbytes.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("shape", "block"))
def toposzp3d_decompress(comp: TopoSZpCompressed, shape: Sequence[int],
                         eb: float, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    nz, ny, nx = shape
    n = nz * ny * nx
    codes = decompress_codes(comp.szp, n, block=block)
    base = dequantize(codes, eb).reshape(shape)

    labels_flat = bitpack.unpack_2bit(comp.labels2b, n)
    labels = labels_flat.reshape(shape)
    n_codes = comp.ranks.widths.shape[0] * block
    rs = decompress_codes(comp.ranks, min(n_codes, n), block=block)
    if n_codes < n:
        rs = jnp.concatenate([rs, jnp.zeros(n - n_codes, jnp.int32)])
    dest = _cp_first_dest(labels_flat)
    ranks = rs[:n][dest].reshape(shape)

    # extrema stencils (6-neighbor) + rank separation
    cur = classify3d(base)
    lost_min = (labels == MINIMA) & (cur != MINIMA)
    lost_max = (labels == MAXIMA) & (cur != MAXIMA)
    nmin, nmax = _neighbor_min_max3d(base)
    delta = jnp.maximum(ranks, 1)
    tgt_min = ulp_step(nmin, -delta)
    tgt_max = ulp_step(nmax, +delta)
    ok_min = lost_min & (tgt_min >= base - eb) & (tgt_min <= base + eb)
    ok_max = lost_max & (tgt_max >= base - eb) & (tgt_max <= base + eb)
    cand = jnp.where(ok_min, tgt_min, base)
    cand = jnp.where(ok_max, tgt_max, cand)
    survive = (labels != REGULAR) & ~(ok_min | ok_max)
    sep = jnp.where(labels == MINIMA, -delta, delta)
    cand = jnp.where(survive, ulp_step(cand, sep), cand)

    # FP/FT suppression (same fixed-point loop as 2-D)
    keep0 = cand != base

    def viol(fld):
        lbl = classify3d(fld)
        return (lbl != REGULAR) & (lbl != labels)

    def cond(state):
        keep, it = state
        return jnp.any(viol(jnp.where(keep, cand, base))) & (it < 32)

    def body(state):
        keep, it = state
        v = viol(jnp.where(keep, cand, base))
        return keep & ~_dilate3d(v), it + 1

    keep, _ = jax.lax.while_loop(cond, body, (keep0, jnp.int32(0)))
    return jnp.where(keep, cand, base)


def false_cases3d(orig, recon):
    lo, lr = classify3d(orig), classify3d(recon)
    fn = (lo != REGULAR) & (lr == REGULAR)
    fp = (lo == REGULAR) & (lr != REGULAR)
    ft = (lo != REGULAR) & (lr != REGULAR) & (lo != lr)
    return {"FN": int(fn.sum()), "FP": int(fp.sum()), "FT": int(ft.sum()),
            "n_cp": int((lo != REGULAR).sum())}
