"""Fixed-length byte encoding (SZp "BE" stage) — static-shape JAX bit packing.

SZp stores, per block of K values, the per-block bit width w_b needed for the
largest |delta| in the block, then packs the magnitudes of all K deltas at
w_b bits each into a contiguous byte stream.  On CPU SZp emits this stream
serially; here the packing is fully parallel:

  * per-block byte counts  nb_b = ceil(K * w_b / 8)
  * byte offsets by exclusive prefix sum
  * every *output byte* is produced independently by gathering the (<= 8)
    value bits it covers (searchsorted maps byte -> block)

Unpacking reads, for each value, the <= 5 bytes its bit-window spans and
reassembles the magnitude with 32-bit shifts.  Both directions are jit-able
with static capacities; the dynamic quantity is the valid byte count.

This mirrors the on-disk format byte-for-byte (see core/io.py), the buffers
are simply over-allocated to the static worst case.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.utils import exclusive_cumsum

MAX_WIDTH = 32


def block_nbytes(widths: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-block packed byte count for K values at widths bits each.

    Widths arrive as the serialized uint8 stream as often as not; the
    product ``k * width`` tops out at 31 * 32 and must not wrap in the
    stream dtype, so compute in int32."""
    return (k * widths.astype(jnp.int32) + 7) // 8


def sum_width(width: int, n_summands: int) -> int:
    """Bit width that holds any sum of ``n_summands`` ``width``-bit magnitudes.

    The block-width growth law of the ring all-reduce (dist/ring.py): a
    partial sum over h members needs at most ``ceil(log2(h))`` extra bits
    over the per-member width, capped at the 32-bit packing limit.
    """
    if n_summands <= 1:
        return min(width, MAX_WIDTH)
    return min(MAX_WIDTH, width + (n_summands - 1).bit_length())


# Static capacity buckets for the two-pass tiled pack: the *measured* max
# block width is lifted to the next bucket so the payload capacity (a static
# shape under jit) shrinks from the 32-bit worst case to ~w_max while the
# small bucket set bounds recompilations to |WIDTH_BUCKETS| variants.
WIDTH_BUCKETS = (1, 2, 4, 8, 16, 32)


def width_bucket(w_max: int) -> int:
    """Smallest static capacity bucket holding measured width ``w_max``."""
    if not 0 <= w_max <= MAX_WIDTH:
        raise ValueError(f"measured width {w_max} outside [0, {MAX_WIDTH}]")
    for b in WIDTH_BUCKETS:
        if w_max <= b:
            return b
    return MAX_WIDTH


def pack_blocks(mags: jnp.ndarray, widths: jnp.ndarray,
                max_width: int = MAX_WIDTH
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pack per-block magnitudes at per-block bit widths.

    Args:
      mags:   (B, K) uint32/int32 magnitudes, each < 2**widths[b].
      widths: (B,) int32 in [0, max_width].  Callers must guarantee the
              bound; it sizes the static output buffer.
      max_width: static cap on every entry of ``widths``.  The ring
              all-reduce passes the deterministic per-hop bound here
              (see :func:`sum_width`) so the shipped buffer shrinks with
              the realizable width instead of the 32-bit worst case.

    Returns:
      buf:    (cap,) uint8 packed stream (valid prefix only),
              cap = B*ceil(K*max_width/8)
      offs:   (B,) int32 exclusive byte offsets per block
      total:  () int32 total valid bytes
    """
    mags = mags.astype(jnp.uint32)
    b_blocks, k = mags.shape
    nb = block_nbytes(widths, k)                       # (B,)
    offs = exclusive_cumsum(nb)                        # (B,)
    total = offs[-1] + nb[-1] if b_blocks > 0 else jnp.int32(0)
    cap = b_blocks * ((k * max_width + 7) // 8)

    j = jnp.arange(cap, dtype=jnp.int32)               # output byte index
    blk = jnp.searchsorted(offs, j, side="right") - 1  # block covering byte j
    blk = jnp.clip(blk, 0, b_blocks - 1)
    jb = j - offs[blk]                                 # byte index inside block
    w = widths[blk]                                    # (cap,)

    # bit positions covered by this byte inside the block's bit stream
    t = jb[:, None] * 8 + jnp.arange(8, dtype=jnp.int32)[None, :]   # (cap, 8)
    w_safe = jnp.maximum(w, 1)[:, None]
    i = jnp.minimum(t // w_safe, k - 1)                # value index
    bit_in_val = t % w_safe
    vals = mags[blk[:, None], i]                       # (cap, 8) gather
    bits = (vals >> bit_in_val.astype(jnp.uint32)) & jnp.uint32(1)
    # mask out bits past the block's bit stream or in zero-width blocks
    valid_bit = (t < (k * w)[:, None]) & (w[:, None] > 0)
    bits = jnp.where(valid_bit, bits, jnp.uint32(0))
    byte = (bits << jnp.arange(8, dtype=jnp.uint32)[None, :]).sum(axis=1)
    byte = jnp.where(j < total, byte, jnp.uint32(0))
    return byte.astype(jnp.uint8), offs, total.astype(jnp.int32)


def local_pack_bytes(mags: jnp.ndarray, widths: jnp.ndarray,
                     max_width: int = MAX_WIDTH) -> jnp.ndarray:
    """Phase 1 of the tiled pack: every block packed at LOCAL offset 0.

    Returns (B, ceil(K*max_width/8)) uint8 — block b's first ``nb_b`` bytes
    are exactly its slice of the :func:`pack_blocks` stream; the tail is 0.
    Per-block independent (no global searchsorted), so the work is
    ``B*ceil(K*w/8)`` bytes instead of the 32-bit worst-case capacity.
    This is the jnp oracle for ``kernels/bitpack_pack.py``.
    """
    mags = mags.astype(jnp.uint32)
    b_blocks, k = mags.shape
    nbm = (k * max_width + 7) // 8
    w = widths.astype(jnp.int32)[:, None, None]             # (B, 1, 1)
    t = (jnp.arange(nbm, dtype=jnp.int32)[:, None] * 8
         + jnp.arange(8, dtype=jnp.int32)[None, :])[None]   # (1, nbm, 8)
    w_safe = jnp.maximum(w, 1)
    i = jnp.minimum(t // w_safe, k - 1)                     # value index
    bit_in_val = (t % w_safe).astype(jnp.uint32)
    vals = jnp.take_along_axis(mags, i.reshape(b_blocks, nbm * 8), axis=1)
    bits = (vals.reshape(b_blocks, nbm, 8) >> bit_in_val) & jnp.uint32(1)
    valid = (t < k * w) & (w > 0)
    bits = jnp.where(valid, bits, jnp.uint32(0))
    byte = (bits << jnp.arange(8, dtype=jnp.uint32)).sum(axis=2)
    return byte.astype(jnp.uint8)


def compact_local_bytes(local: jnp.ndarray, widths: jnp.ndarray, k: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Phase 2 of the tiled pack: scatter per-block local bytes to their
    global offsets.  Offsets are disjoint, so the scatter is collision-free
    and deterministic; bytes past ``total`` stay 0 (matching
    :func:`pack_blocks`).  Returns the same (buf, offs, total) contract with
    cap = B * local.shape[1]."""
    b_blocks, nbm = local.shape
    nb = block_nbytes(widths, k)                            # (B,)
    offs = exclusive_cumsum(nb)
    total = offs[-1] + nb[-1] if b_blocks > 0 else jnp.int32(0)
    cap = b_blocks * nbm
    jb = jnp.arange(nbm, dtype=jnp.int32)[None, :]          # (1, nbm)
    # invalid slots all map to the dropped index `cap`, so the indices are
    # NOT unique — don't assert unique_indices (UB under duplicates).
    idx = jnp.where(jb < nb[:, None], offs[:, None] + jb, cap)
    buf = jnp.zeros(cap, jnp.uint8).at[idx.reshape(-1)].set(
        local.reshape(-1), mode="drop")
    return buf, offs, total.astype(jnp.int32)


def pack_blocks_tiled(mags: jnp.ndarray, widths: jnp.ndarray,
                      max_width: int = MAX_WIDTH
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Two-phase tiled pack: bit-identical valid prefix to
    :func:`pack_blocks`, same (buf, offs, total) contract, but the capacity
    and the per-byte gather work scale with ``max_width`` (the measured max
    width lifted to a :data:`WIDTH_BUCKETS` entry) instead of 32 bits."""
    return compact_local_bytes(local_pack_bytes(mags, widths, max_width),
                               widths, mags.shape[1])


def unpack_blocks(buf: jnp.ndarray, widths: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of :func:`pack_blocks` -> (B, K) uint32 magnitudes."""
    b_blocks = widths.shape[0]
    nb = block_nbytes(widths, k)
    offs = exclusive_cumsum(nb)

    w = widths[:, None]                                 # (B, 1)
    i = jnp.arange(k, dtype=jnp.int32)[None, :]         # (1, K)
    s = i * w                                           # bit start inside block
    byte0 = offs[:, None] + s // 8                      # absolute first byte
    sh = (s % 8).astype(jnp.uint32)

    cap = buf.shape[0]
    idx = byte0[:, :, None] + jnp.arange(5, dtype=jnp.int32)[None, None, :]
    idx = jnp.clip(idx, 0, cap - 1)
    bts = buf[idx].astype(jnp.uint32)                   # (B, K, 5)

    lo = bts[..., 0] | (bts[..., 1] << 8) | (bts[..., 2] << 16) | (bts[..., 3] << 24)
    hi = bts[..., 4]
    # value = (lo >> sh) | (hi << (32 - sh)), guarding the sh == 0 case
    # (shifting a uint32 by 32 is undefined in XLA).
    up = jnp.where(sh == 0, jnp.uint32(0), hi << (jnp.uint32(32) - sh))
    val = (lo >> sh) | up
    # mask to w bits; w == 32 keeps everything, w == 0 yields 0.
    wq = w.astype(jnp.uint32)
    mask = jnp.where(
        wq >= 32, jnp.uint32(0xFFFFFFFF),
        (jnp.uint32(1) << jnp.where(wq >= 32, jnp.uint32(0), wq)) - jnp.uint32(1))
    val = val & mask
    return jnp.where(w > 0, val, jnp.uint32(0))


# ---- fixed-width helpers (sign bits, 2-bit label maps) ---------------------

def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a flat {0,1} array into uint8 bytes (little-endian bit order)."""
    n = bits.shape[0]
    pad = (-n) % 8
    b = jnp.pad(bits.astype(jnp.uint32), (0, pad)).reshape(-1, 8)
    return (b << jnp.arange(8, dtype=jnp.uint32)[None, :]).sum(axis=1) \
        .astype(jnp.uint8)


def unpack_bits(buf: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`; returns (n,) uint8 of {0,1}."""
    bits = (buf[:, None].astype(jnp.uint32)
            >> jnp.arange(8, dtype=jnp.uint32)[None, :]) & 1
    return bits.reshape(-1)[:n].astype(jnp.uint8)


def pack_2bit(vals: jnp.ndarray) -> jnp.ndarray:
    """Pack a flat array of 2-bit codes (0..3) into bytes, 4 per byte."""
    n = vals.shape[0]
    pad = (-n) % 4
    v = jnp.pad(vals.astype(jnp.uint32), (0, pad)).reshape(-1, 4)
    return (v << (2 * jnp.arange(4, dtype=jnp.uint32))[None, :]).sum(axis=1) \
        .astype(jnp.uint8)


def unpack_2bit(buf: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_2bit`; returns (n,) int32 codes in 0..3."""
    v = (buf[:, None].astype(jnp.uint32)
         >> (2 * jnp.arange(4, dtype=jnp.uint32))[None, :]) & 3
    return v.reshape(-1)[:n].astype(jnp.int32)
