"""RBF saddle refinement (paper Sec. IV-B, "RS-hat" stage).

Lost saddles are re-estimated from a k x k neighborhood (k in {3,5,7},
adaptive) with normalized Gaussian RBF weights — a *convex* combination
(alpha_i >= 0, sum alpha_i = 1, eq. (2) of the paper; see DESIGN.md on why
the normalized/Shepard form is the faithful realization of eq. (2)).  An
exact Gaussian-RBF interpolation solve is available as `rbf_mode="interp"`
for ablation.

Adaptive parameters (paper "Adaptive parameters" paragraph):
  * kernel width sigma in [0.5, 1.0], scaled with normalized local variation
    (smooth neighborhood -> larger sigma);
  * kernel radius r in {1,2,3} (k = 2r+1), larger when *global* variation is
    low; realized as a dynamic radius mask over a static 7x7 gather.

The update is applied only where (a) it stays within +-eb of the SZp
reconstruction (total error <= 2 eb) and (b) it actually restores the strict
saddle pattern; FP/FT suppression happens globally afterwards
(core/guarantees.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.critical_points import SADDLE, classify
from repro.kernels import ops

MAX_RADIUS = 3  # static gather window 7x7; effective radius is dynamic


def _window_patches(field: jnp.ndarray, radius: int = MAX_RADIUS) -> jnp.ndarray:
    """(ny, nx, (2r+1)^2) neighborhood patches (edge-replicated)."""
    k = 2 * radius + 1
    pad = jnp.pad(field, radius, mode="edge")
    rows = []
    for dy in range(k):
        for dx in range(k):
            rows.append(pad[dy:dy + field.shape[0], dx:dx + field.shape[1]])
    return jnp.stack(rows, axis=-1)


def _offsets(radius: int = MAX_RADIUS) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = 2 * radius + 1
    dy, dx = jnp.meshgrid(jnp.arange(-radius, radius + 1),
                          jnp.arange(-radius, radius + 1), indexing="ij")
    return dy.reshape(k * k), dx.reshape(k * k)


def adaptive_params(field: jnp.ndarray, eb: float):
    """(sigma map, radius map) from local / global variation heuristics."""
    patches = _window_patches(field, 1)                  # 3x3 local variation
    local_var = patches.max(-1) - patches.min(-1)
    scale = jnp.maximum(field.max() - field.min(), 1e-30)
    nv = jnp.clip(local_var / scale, 0.0, 1.0)           # normalized variation
    sigma = 1.0 - 0.5 * nv                               # in [0.5, 1.0]
    gv = jnp.clip((field.std() / scale), 0.0, 1.0)       # global variation
    # low global variation -> radius 3 (k=7); high -> radius 1 (k=3)
    radius = jnp.where(gv < 0.05, 3, jnp.where(gv < 0.2, 2, 1))
    radius = jnp.broadcast_to(radius, field.shape)
    return sigma, radius


def shepard_refine(field: jnp.ndarray, sigma: jnp.ndarray,
                   radius: jnp.ndarray) -> jnp.ndarray:
    """Convex normalized-Gaussian-RBF estimate of every point from its
    neighborhood (center excluded).  Returns the refined value map."""
    patches = _window_patches(field, MAX_RADIUS)         # (ny, nx, 49)
    dy, dx = _offsets(MAX_RADIUS)
    dist2 = (dy ** 2 + dx ** 2).astype(jnp.float32)      # (49,)
    center = dist2 == 0
    w = jnp.exp(-dist2[None, None, :] / (2.0 * sigma[..., None] ** 2))
    within = (jnp.maximum(jnp.abs(dy), jnp.abs(dx))[None, None, :]
              <= radius[..., None])
    w = jnp.where(center[None, None, :] | ~within, 0.0, w)
    wsum = jnp.maximum(w.sum(-1), 1e-30)
    return (w * patches).sum(-1) / wsum                   # convex combination


def interp_refine(field: jnp.ndarray, sigma: jnp.ndarray,
                  saddle_mask: jnp.ndarray, radius_static: int = 1) -> jnp.ndarray:
    """Exact Gaussian-RBF interpolation solve per lost saddle (ablation mode).

    Solves Phi w = f over the (2r+1)^2 - 1 neighbors and evaluates at the
    center.  O(k^6) per point — run only at flagged points, scattered back.
    """
    k = 2 * radius_static + 1
    m = k * k
    patches = _window_patches(field, radius_static)       # (ny, nx, m)
    dy, dx = _offsets(radius_static)
    keep = ~((dy == 0) & (dx == 0))
    dyk, dxk = dy[keep], dx[keep]
    vals = patches[..., keep]                             # (ny, nx, m-1)
    # pairwise kernel matrix between neighbor offsets (same for all points)
    d2 = (dyk[:, None] - dyk[None, :]) ** 2 + (dxk[:, None] - dxk[None, :]) ** 2
    s2 = jnp.maximum(sigma, 0.5) ** 2                     # (ny, nx)
    phi = jnp.exp(-d2[None, None] / (2.0 * s2[..., None, None]))
    phi = phi + 1e-4 * jnp.eye(m - 1)[None, None]         # ridge for stability
    w = jnp.linalg.solve(phi, vals[..., None])[..., 0]    # (ny, nx, m-1)
    phi0 = jnp.exp(-(dyk ** 2 + dxk ** 2)[None, None] / (2.0 * s2[..., None]))
    est = (w * phi0).sum(-1)
    return jnp.where(saddle_mask, est, field)


def global_shepard_params(field: jnp.ndarray, eb: float):
    """Scalar (sigma, radius) for the separable kernel path: the adaptive
    sigma law collapsed to its field mean, radius from the (already
    global) variation rule.  Traced scalars — no static recompiles."""
    sigma, radius = adaptive_params(field, eb)
    return jnp.mean(sigma), radius.reshape(-1)[0]


def refine_saddles(recon: jnp.ndarray, labels: jnp.ndarray, eb: float,
                   rbf_mode: str = "shepard",
                   backend: Optional[str] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Refine lost saddles; returns (field, applied mask).

    ``backend=None`` keeps the per-point-adaptive pure-jnp estimator; a
    kernels.ops backend runs the separable global-parameter Shepard kernel
    (``rbf_mode="shepard"`` only — "interp" always takes the jnp solve).
    The 2*eb clamp and the restored-saddle check are identical either way,
    so the TopoSZp guarantees are estimator-independent.
    """
    recon = recon.astype(jnp.float32)
    if backend is not None and rbf_mode == "shepard":
        cur = ops.cp_detect(recon, backend=backend)
        lost = (labels == SADDLE) & (cur != SADDLE)
        sigma_g, radius_g = global_shepard_params(recon, eb)
        est = ops.shepard_refine(recon, sigma_g, radius_g, backend=backend)
        cand_val = jnp.clip(est, recon - eb, recon + eb)
        cand = jnp.where(lost, cand_val, recon)
        ok = lost & (ops.cp_detect(cand, backend=backend) == SADDLE)
        return jnp.where(ok, cand, recon), ok
    cur = classify(recon)
    lost = (labels == SADDLE) & (cur != SADDLE)

    sigma, radius = adaptive_params(recon, eb)
    if rbf_mode == "shepard":
        est = shepard_refine(recon, sigma, radius)
    elif rbf_mode == "interp":
        est = interp_refine(recon, sigma, lost)
    else:
        raise ValueError(f"unknown rbf_mode: {rbf_mode}")

    # hard 2eb guarantee: movement capped at +-eb around the SZp recon
    cand_val = jnp.clip(est, recon - eb, recon + eb)
    cand = jnp.where(lost, cand_val, recon)

    # keep only updates that actually restore the strict saddle pattern
    new_labels = classify(cand)
    ok = lost & (new_labels == SADDLE)
    out = jnp.where(ok, cand, recon)
    return out, ok
