"""Relative positioning metadata (paper Sec. IV-A, "RP" stage).

For critical points that fall into the *same* quantization bin, an integer
rank encodes their original value ordering so the decompressor can separate
them again (paper Fig. 5).  Ranks are stored densely (0 at regular points)
and re-compressed losslessly with a second B+LZ+BE pass (paper Sec. IV-A:
"we apply the B+LZ and BE stages a second time ... we omit QZ for this
metadata since it ... must remain lossless").

Direction convention (DESIGN.md clarification): maxima and saddles are
ranked *ascending* by value (rank 1 = smallest), minima *descending*
(rank 1 = largest), so that the +-delta-ULP stencils in core/stencils.py
restore the original order for both extrema kinds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.critical_points import MINIMA, REGULAR


def compute_ranks(field: jnp.ndarray, labels: jnp.ndarray,
                  codes: jnp.ndarray) -> jnp.ndarray:
    """Per-point rank among same-(bin, type) critical points.

    Args:
      field:  (ny, nx) float32 original values.
      labels: (ny, nx) int32 CD labels.
      codes:  (ny, nx) int32 quantization bin indices.

    Returns:
      (ny, nx) int32 ranks; 0 at regular points, >= 1 at critical points.
    """
    f = field.astype(jnp.float32).reshape(-1)
    lab = labels.reshape(-1)
    q = codes.reshape(-1)
    n = f.shape[0]

    is_cp = lab != REGULAR
    # group = (bin, type'); regular points get the sentinel type 4 so they
    # form their own (masked-out) segments wherever they land — no separate
    # primary key pushing them to the end, which drops the comparator from
    # four keys to three (x32-safe: no combined 64-bit key) and is worth
    # ~30% of the sort on the XLA CPU hot path.
    lab4 = jnp.where(is_cp, lab, jnp.int32(4))
    # secondary sort key: value ascending, except minima descending.
    sec = jnp.where(lab == MINIMA, -f, f)

    # lexsort: last key is primary -> (bin, type', value)
    order = jnp.lexsort((sec, lab4, q))
    q_s, lab_s, cp_s = q[order], lab4[order], is_cp[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    new_seg = jnp.concatenate([
        jnp.array([True]),
        (q_s[1:] != q_s[:-1]) | (lab_s[1:] != lab_s[:-1]),
    ])
    seg_start = jax.lax.associative_scan(jnp.maximum, jnp.where(new_seg, pos, 0))
    rank_sorted = pos - seg_start + 1
    ranks = jnp.zeros(n, jnp.int32).at[order].set(
        jnp.where(cp_s, rank_sorted.astype(jnp.int32), 0))
    return ranks.reshape(field.shape)
