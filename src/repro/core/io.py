"""On-disk serialization of SZp / TopoSZp streams (paper Fig. 6 layout).

The jit-side pipeline keeps the sections as separate fixed-capacity arrays;
this module materializes the actual byte stream (header + sections in Fig. 6
order, payload sliced to its valid length) and parses it back.  Used by the
checkpoint manager and by the true-size accounting in the benchmarks.
"""
from __future__ import annotations

import struct
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.bitpack import width_bucket
from repro.core.szp import DEFAULT_BLOCK, SZpParts
from repro.core.toposzp import TopoSZpCompressed

MAGIC = b"SZPJ"
MAGIC_TOPO = b"TSZP"
STREAM_VERSION = 1
_HDR = struct.Struct("<4sIIIIdI")  # magic, version, ny, nx, block, eb, nblocks


class BadStreamError(ValueError):
    """Raised when a serialized SZp/TopoSZp stream is malformed (bad magic,
    unsupported version, truncated sections).  The checkpoint restore path
    treats it as a corrupt blob and falls back to an older checkpoint."""


def peek_magic(buf: bytes) -> bytes:
    """Magic of a serialized stream without parsing it (b'SZPJ'/b'TSZP')."""
    if len(buf) < 4:
        raise BadStreamError(f"stream too short ({len(buf)} bytes)")
    return bytes(buf[:4])


def _np(a) -> np.ndarray:
    return np.asarray(a)


def serialize_szp(parts: SZpParts, shape: Tuple[int, int], eb: float,
                  block: int = DEFAULT_BLOCK, magic: bytes = MAGIC) -> bytes:
    ny, nx = shape
    nblocks = int(_np(parts.widths).shape[0])
    payload = _np(parts.payload)[: int(parts.payload_nbytes)]
    hdr = _HDR.pack(magic, 1, ny, nx, block, float(eb), nblocks)
    return b"".join([
        hdr,
        _np(parts.const_bits).tobytes(),
        _np(parts.widths).tobytes(),
        _np(parts.signs).tobytes(),
        _np(parts.first).astype("<i4").tobytes(),
        payload.tobytes(),
    ])


def deserialize_szp(buf: bytes) -> Tuple[SZpParts, Tuple[int, int], float, int]:
    if len(buf) < _HDR.size:
        raise BadStreamError(f"stream too short ({len(buf)} bytes)")
    magic, ver, ny, nx, block, eb, nblocks = _HDR.unpack_from(buf, 0)
    if magic not in (MAGIC, MAGIC_TOPO):
        raise BadStreamError(f"bad magic {magic!r}")
    if ver != STREAM_VERSION:
        raise BadStreamError(f"unsupported stream version {ver}")
    off = _HDR.size
    n_const = -(-nblocks // 8)
    n_sign = -(-(nblocks * block) // 8)
    const_bits = np.frombuffer(buf, np.uint8, n_const, off); off += n_const
    widths = np.frombuffer(buf, np.uint8, nblocks, off); off += nblocks
    signs = np.frombuffer(buf, np.uint8, n_sign, off); off += n_sign
    first = np.frombuffer(buf, "<i4", nblocks, off); off += 4 * nblocks
    payload = np.frombuffer(buf, np.uint8, len(buf) - off, off)
    # capacity = the stream's width BUCKET (not the 32-bit worst case, and
    # not the exact byte count either: capacity is a static shape under
    # jit, so it must be a function of (nblocks, bucket) — a small set —
    # or every distinct payload length would recompile the decompress
    # graph).  Safe because unpack_blocks masks every magnitude to its
    # block width, so clamped reads never leak past-the-end bytes.
    w_max = int(widths.max(initial=0))
    wb = width_bucket(min(w_max, 32))
    cap = max(nblocks * (((block - 1) * wb + 7) // 8), payload.shape[0], 1)
    pay = np.zeros(cap, np.uint8)
    pay[: payload.shape[0]] = payload
    parts = SZpParts(jnp.asarray(const_bits), jnp.asarray(widths),
                     jnp.asarray(signs), jnp.asarray(first.copy()),
                     jnp.asarray(pay), jnp.int32(payload.shape[0]),
                     jnp.int32(len(buf)))
    return parts, (ny, nx), eb, block


def _trim_rank_parts(parts: SZpParts, n_cp: int, block: int) -> SZpParts:
    """Slice the sparse rank stream to its used block prefix (the CP-first
    sort guarantees everything past ceil(n_cp/block) blocks is zero)."""
    ub = max(1, -(-n_cp // block))
    return SZpParts(
        jnp.asarray(_np(parts.const_bits)[: -(-ub // 8)]),
        jnp.asarray(_np(parts.widths)[:ub]),
        jnp.asarray(_np(parts.signs)[: -(-(ub * block) // 8)]),
        jnp.asarray(_np(parts.first)[:ub]),
        parts.payload, parts.payload_nbytes, parts.nbytes)


def serialize_toposzp(comp: TopoSZpCompressed, shape: Tuple[int, int],
                      eb: float, block: int = DEFAULT_BLOCK) -> bytes:
    base = serialize_szp(comp.szp, shape, eb, block, magic=MAGIC_TOPO)
    labels = _np(comp.labels2b).tobytes()
    n_cp = int(comp.n_cp)
    trimmed = _trim_rank_parts(comp.ranks, n_cp, block)
    ranks = serialize_szp(trimmed, shape, eb, block)
    return b"".join([
        struct.pack("<IIII", len(base), len(labels), len(ranks), n_cp),
        base, labels, ranks,
    ])


def deserialize_toposzp(buf: bytes):
    if len(buf) < 16:
        raise BadStreamError(f"stream too short ({len(buf)} bytes)")
    n_base, n_labels, n_ranks, n_cp = struct.unpack_from("<IIII", buf, 0)
    if 16 + n_base + n_labels + n_ranks > len(buf):
        raise BadStreamError("truncated TopoSZp stream")
    off = 16
    szp_parts, shape, eb, block = deserialize_szp(buf[off:off + n_base])
    off += n_base
    labels2b = jnp.asarray(np.frombuffer(buf, np.uint8, n_labels, off).copy())
    off += n_labels
    rank_parts, _, _, _ = deserialize_szp(buf[off:off + n_ranks])
    comp = TopoSZpCompressed(szp_parts, labels2b, rank_parts,
                             jnp.int32(n_cp), jnp.int32(len(buf)))
    return comp, shape, eb, block
