"""SZp compression pipeline in JAX: QZ -> B + LZ (block delta) -> BE.

Stream layout follows the paper's Fig. 6 (sections 1-5; TopoSZp adds 6-7 in
core/toposzp.py):

  (1) constant-block bitmap            ceil(B/8) bytes
  (2) fixed-length block metadata      B bytes (per-block bit width)
  (3) sign bits for all elements       ceil(n_pad/8) bytes
  (4) first-element value per block    4*B bytes (quantized int32 outlier)
  (5) packed magnitude byte stream     variable (sum of per-block widths)

All stages are jit-able with static shapes; compressed buffers are fixed
*capacity* with a dynamic valid ``nbytes`` (see DESIGN.md hardware notes).
A lossless integer mode (used for the TopoSZp rank metadata, which must not
be quantized) reuses stages (1)-(5) on raw int32 values.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.quantize import dequantize, quantize
from repro.utils import bitwidth, cdiv, pad_to_multiple

DEFAULT_BLOCK = 32
HEADER_BYTES = 32  # magic/version/n/shape/block/eb — accounted, materialized in io.py


class SZpParts(NamedTuple):
    """Compressed SZp stream (sections as arrays + dynamic byte count)."""
    const_bits: jnp.ndarray      # packed constant-block bitmap
    widths: jnp.ndarray          # (B,) uint8 per-block bit width
    signs: jnp.ndarray           # packed delta sign bits (n_pad bits)
    first: jnp.ndarray           # (B,) int32 first-element (outlier) codes
    payload: jnp.ndarray         # (cap,) uint8 packed magnitudes
    payload_nbytes: jnp.ndarray  # () int32 valid payload bytes
    nbytes: jnp.ndarray          # () int32 total compressed size (with header)


def _blocked_codes(codes: jnp.ndarray, block: int) -> jnp.ndarray:
    q = pad_to_multiple(codes, block, axis=0, mode="edge")
    return q.reshape(-1, block)


def compress_codes(codes: jnp.ndarray, block: int = DEFAULT_BLOCK) -> SZpParts:
    """Lossless stages (1)-(5) over int32 codes (B + LZ + BE)."""
    qb = _blocked_codes(codes.astype(jnp.int32).ravel(), block)
    nblocks, k = qb.shape
    first = qb[:, 0]
    deltas = qb[:, 1:] - qb[:, :-1]                       # (B, K-1) intra-block LZ
    signs = jnp.concatenate(
        [jnp.zeros((nblocks, 1), jnp.uint8), (deltas < 0).astype(jnp.uint8)], axis=1)
    mags = jnp.abs(deltas).astype(jnp.uint32)
    widths = bitwidth(mags.max(axis=1))                    # (B,)
    payload, _, total = bitpack.pack_blocks(mags, widths)
    const_bits = bitpack.pack_bits((widths == 0).astype(jnp.uint8))
    signs_packed = bitpack.pack_bits(signs.reshape(-1))
    nbytes = (HEADER_BYTES + const_bits.shape[0] + nblocks
              + signs_packed.shape[0] + 4 * nblocks + total)
    return SZpParts(const_bits, widths.astype(jnp.uint8), signs_packed,
                    first, payload, total, nbytes.astype(jnp.int32))


def decompress_codes(parts: SZpParts, n: int,
                     block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Invert :func:`compress_codes` -> (n,) int32 codes."""
    widths = parts.widths.astype(jnp.int32)
    nblocks = widths.shape[0]
    k = block
    mags = bitpack.unpack_blocks(parts.payload, widths, k - 1)  # (B, K-1)
    signs = bitpack.unpack_bits(parts.signs, nblocks * k).reshape(nblocks, k)
    deltas = jnp.where(signs[:, 1:] > 0, -(mags.astype(jnp.int32)),
                       mags.astype(jnp.int32))
    q = parts.first[:, None] + jnp.concatenate(
        [jnp.zeros((nblocks, 1), jnp.int32), jnp.cumsum(deltas, axis=1)], axis=1)
    return q.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("block",))
def szp_compress(x: jnp.ndarray, eb: float, block: int = DEFAULT_BLOCK) -> SZpParts:
    """Full SZp compression of a float field (any shape; flattened row-major)."""
    codes = quantize(x.reshape(-1), eb)
    return compress_codes(codes, block=block)


@functools.partial(jax.jit, static_argnames=("shape", "block", "recon"))
def szp_decompress(parts: SZpParts, shape: Sequence[int], eb: float,
                   block: int = DEFAULT_BLOCK, recon: str = "center") -> jnp.ndarray:
    """Full SZp decompression back to a float field of ``shape``."""
    n = 1
    for s in shape:
        n *= s
    codes = decompress_codes(parts, n, block=block)
    return dequantize(codes, eb, recon=recon).reshape(shape)


def szp_roundtrip(x: jnp.ndarray, eb: float, block: int = DEFAULT_BLOCK
                  ) -> Tuple[jnp.ndarray, SZpParts]:
    parts = szp_compress(x, eb, block=block)
    return szp_decompress(parts, tuple(x.shape), eb, block=block), parts


def compression_ratio(x: jnp.ndarray, parts: SZpParts) -> jnp.ndarray:
    raw = x.size * x.dtype.itemsize
    return raw / parts.nbytes.astype(jnp.float32)


def num_blocks(n: int, block: int = DEFAULT_BLOCK) -> int:
    return cdiv(n, block)
