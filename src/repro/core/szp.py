"""SZp compression pipeline in JAX: QZ -> B + LZ (block delta) -> BE.

Stream layout follows the paper's Fig. 6 (sections 1-5; TopoSZp adds 6-7 in
core/toposzp.py):

  (1) constant-block bitmap            ceil(B/8) bytes
  (2) fixed-length block metadata      B bytes (per-block bit width)
  (3) sign bits for all elements       ceil(n_pad/8) bytes
  (4) first-element value per block    4*B bytes (quantized int32 outlier)
  (5) packed magnitude byte stream     variable (sum of per-block widths)

The float pipeline dispatches its QZ+LZ / QZ^ math through ``kernels.ops``
(``backend={"pallas","interpret","jnp"}``; streams are bit-identical across
backends) and runs the BE stage as a TWO-PASS tiled pack: pass 1 measures
the per-block widths, the max width is lifted to a static
``bitpack.WIDTH_BUCKETS`` capacity on the host, and pass 2 packs at that
capacity — ``B*ceil(K*w_bucket/8)`` bytes instead of the 32-bit worst case
(typically 4-8x less buffer and gather work).  ``compress_codes`` /
``decompress_codes`` keep the one-shot jit-able worst-case form for
callers that embed them in a larger jit (core/baselines.py, core/topo3d.py)
and for the lossless integer mode (the TopoSZp rank metadata).
"""
from __future__ import annotations

import contextlib
import functools
import warnings
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitpack
from repro.kernels import ops
from repro.utils import bitwidth, cdiv, pad_to_multiple

DEFAULT_BLOCK = 32
HEADER_BYTES = 32  # magic/version/n/shape/block/eb — accounted, materialized in io.py

# f32 integer-exactness limit of the MXU tri-matmul dequant (kernels/
# szp_quant.py): every partial delta sum must stay below 2^24.
TRI_DEQUANT_EXACT = 1 << 24


class SZpParts(NamedTuple):
    """Compressed SZp stream (sections as arrays + dynamic byte count)."""
    const_bits: jnp.ndarray      # packed constant-block bitmap
    widths: jnp.ndarray          # (B,) uint8 per-block bit width
    signs: jnp.ndarray           # packed delta sign bits (n_pad bits)
    first: jnp.ndarray           # (B,) int32 first-element (outlier) codes
    payload: jnp.ndarray         # (cap,) uint8 packed magnitudes
    payload_nbytes: jnp.ndarray  # () int32 valid payload bytes
    nbytes: jnp.ndarray          # () int32 total compressed size (with header)


def _blocked_codes(codes: jnp.ndarray, block: int) -> jnp.ndarray:
    q = pad_to_multiple(codes, block, axis=0, mode="edge")
    return q.reshape(-1, block)


def _blocked_field(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """(B, K) blocked float view; edge padding == padding the codes."""
    f = pad_to_multiple(x.astype(jnp.float32).reshape(-1), block, axis=0,
                        mode="edge")
    return f.reshape(-1, block)


def _delta_blocks(qb: jnp.ndarray):
    """B + LZ over (B, K) int32 codes -> (first, mags, signs, widths)."""
    first = qb[:, 0]
    deltas = qb[:, 1:] - qb[:, :-1]                       # (B, K-1)
    signs = (deltas < 0).astype(jnp.int32)
    mags = jnp.abs(deltas).astype(jnp.uint32)
    widths = bitwidth(mags.max(axis=1))                    # (B,)
    return first, mags, signs, widths


def _assemble_parts(first, mags, signs, widths, max_width: int,
                    backend: Optional[str] = None) -> SZpParts:
    """BE stage + fixed sections -> SZpParts (jit-able at static max_width).

    ``backend=None`` keeps the legacy one-shot worst-case packer (no tile
    kernel, 32-bit capacity); a resolved backend runs the tiled two-phase
    pack at the static ``max_width`` bucket.
    """
    nblocks = first.shape[0]
    if backend is None:
        payload, _, total = bitpack.pack_blocks(mags, widths,
                                                max_width=max_width)
    else:
        local = ops.local_pack(mags, widths, max_width=max_width,
                               backend=backend)
        payload, _, total = ops.compact_bytes(local, widths, mags.shape[1],
                                              backend=backend)
    const_bits = bitpack.pack_bits((widths == 0).astype(jnp.uint8))
    signs_full = jnp.concatenate(
        [jnp.zeros((nblocks, 1), jnp.int32), signs], axis=1)
    signs_packed = bitpack.pack_bits(signs_full.reshape(-1).astype(jnp.uint8))
    nbytes = (HEADER_BYTES + const_bits.shape[0] + nblocks
              + signs_packed.shape[0] + 4 * nblocks + total)
    return SZpParts(const_bits, widths.astype(jnp.uint8), signs_packed,
                    first, payload, total, nbytes.astype(jnp.int32))


def compress_codes(codes: jnp.ndarray, block: int = DEFAULT_BLOCK) -> SZpParts:
    """Lossless stages (1)-(5) over int32 codes (B + LZ + BE).

    One-shot, fully jit-able (worst-case 32-bit payload capacity); the
    float pipeline below uses the two-pass tiled pack instead.
    """
    qb = _blocked_codes(codes.astype(jnp.int32).ravel(), block)
    first, mags, signs, widths = _delta_blocks(qb)
    return _assemble_parts(first, mags, signs, widths, bitpack.MAX_WIDTH)


def decompress_codes(parts: SZpParts, n: int,
                     block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Invert :func:`compress_codes` -> (n,) int32 codes (exact int path)."""
    mags, signs, nblocks = _unpack_sections(parts, block)
    deltas = jnp.where(signs[:, 1:] > 0, -(mags.astype(jnp.int32)),
                       mags.astype(jnp.int32))
    q = parts.first[:, None] + jnp.concatenate(
        [jnp.zeros((nblocks, 1), jnp.int32), jnp.cumsum(deltas, axis=1)],
        axis=1)
    return q.reshape(-1)[:n]


def _unpack_sections(parts: SZpParts, block: int):
    """BE^ over sections (2)/(3)/(5) -> (mags (B,K-1), signs (B,K), B)."""
    widths = parts.widths.astype(jnp.int32)
    nblocks = widths.shape[0]
    mags = bitpack.unpack_blocks(parts.payload, widths, block - 1)
    signs = bitpack.unpack_bits(parts.signs, nblocks * block) \
        .reshape(nblocks, block)
    return mags, signs, nblocks


# --------------------------------------------------------------------------
# Float pipeline: backend-threaded two-pass compress / guarded decompress
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _quant_stage(x: jnp.ndarray, eb: float, block: int, backend: str):
    """Pass 1: fused QZ+LZ through kernels.ops + measured max width."""
    with jax.named_scope("szp.stage_quant"):
        xb = _blocked_field(x, block)
        first, mags, signs, widths = ops.szp_quant(xb, eb, backend=backend)
        return first, mags, signs, widths, widths.max()


@functools.partial(jax.jit, static_argnames=("max_width", "backend"))
def _pack_stage(first, mags, signs, widths, max_width: int,
                backend: str) -> SZpParts:
    """Pass 2: tiled BE pack at the static capacity bucket."""
    with jax.named_scope("szp.stage_pack"):
        return _assemble_parts(first, mags, signs, widths, max_width,
                               backend=backend)


def _obs_stream(parts: SZpParts, pipeline: str, mode: str) -> None:
    """Static stream accounting: calls + the capacity-formula bytes.

    Every number here comes from array SHAPES (aval metadata, host-known
    without any device read), so recording it keeps the zero-sync
    guarantee on both the classic and the resident path."""
    if not obs.enabled():
        return
    batched = parts.widths.ndim == 2
    calls = parts.widths.shape[0] if batched else 1
    cap = (HEADER_BYTES * calls + parts.const_bits.size + parts.widths.size
           + parts.signs.size + 4 * parts.first.size + parts.payload.size)
    obs.counter_add(f"{pipeline}.compress.calls", calls)
    obs.counter_add(f"{pipeline}.compress.{mode}_calls", calls)
    obs.counter_add(f"{pipeline}.compress.cap_bytes", float(cap))


def _bucket_index(w_max: jnp.ndarray) -> jnp.ndarray:
    """Device-side :func:`bitpack.width_bucket`: index into WIDTH_BUCKETS."""
    edges = jnp.asarray(bitpack.WIDTH_BUCKETS[:-1], jnp.int32)
    return (w_max.astype(jnp.int32) > edges).sum()


def _worst_payload_cap(nblocks: int, block: int) -> int:
    """Static payload capacity shared by every ``lax.switch`` branch."""
    return nblocks * (((block - 1) * bitpack.MAX_WIDTH + 7) // 8)


def _pack_switch(streams, block: int, backend: str,
                 batched: bool = False):
    """On-device bucket select + BE pack of one or more delta streams.

    ``streams`` is a tuple of ``(first, mags, signs, widths)`` tuples; all
    of them are packed at the SHARED bucket of the global max width (one
    ``lax.switch`` branch per static WIDTH_BUCKETS capacity instead of a
    branch per bucket combination).  Every branch zero-pads its payloads to
    the worst-case capacity so the branch avals match; the valid prefix
    and all byte counts are untouched, so serialized streams stay
    bit-identical to the host-bucketed two-pass pack.  Returns a tuple of
    SZpParts, one per stream."""
    bdim = 1 if batched else 0
    caps = [_worst_payload_cap(s[0].shape[bdim], block) for s in streams]

    def branch(mw):
        def pack_one(args, cap):
            if batched:
                parts = jax.vmap(lambda f, m, s, w: _assemble_parts(
                    f, m, s, w, mw, backend=backend))(*args)
                pad = ((0, 0), (0, cap - parts.payload.shape[1]))
            else:
                parts = _assemble_parts(*args, mw, backend=backend)
                pad = (0, cap - parts.payload.shape[0])
            return parts._replace(payload=jnp.pad(parts.payload, pad))

        def fn(streams):
            return tuple(pack_one(s, c) for s, c in zip(streams, caps))
        return fn

    w_max = functools.reduce(jnp.maximum,
                             [s[3].max() for s in streams]).astype(jnp.int32)
    bidx = _bucket_index(w_max)
    return jax.lax.switch(bidx, [branch(m) for m in bitpack.WIDTH_BUCKETS],
                          tuple(streams))


def _compress_resident(x: jnp.ndarray, eb, block: int,
                       backend: str) -> SZpParts:
    """Device-resident compress: quant + bucket select + pack, no host."""
    with jax.named_scope("szp.stage_quant"):
        xb = _blocked_field(x, block)
        first, mags, signs, widths = ops.szp_quant(xb, eb, backend=backend)
    with jax.named_scope("szp.stage_pack"):
        (parts,) = _pack_switch(((first, mags, signs, widths),), block,
                                backend)
    return parts


_compress_resident_jit = jax.jit(
    _compress_resident, static_argnames=("block", "backend"))
_compress_resident_donated = jax.jit(
    _compress_resident, static_argnames=("block", "backend"),
    donate_argnums=(0,))


@contextlib.contextmanager
def _quiet_donation():
    """Donation is best-effort: no compress output matches the input's
    f32 aval, so backends that only reuse donated buffers via exact
    aliasing (CPU) warn and keep the input alive.  The flag still frees
    the buffer where the allocator supports it (TPU)."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def szp_compress(x: jnp.ndarray, eb, block: int = DEFAULT_BLOCK,
                 backend: Optional[str] = None, resident: bool = False,
                 donate: bool = False) -> SZpParts:
    """Full SZp compression of a float field (any shape; flattened
    row-major).  Stream bytes are bit-identical across backends and modes.

    ``resident=False`` (default) keeps the two-pass pack: one host sync
    reads the measured max width and the payload capacity is the measured
    WIDTH_BUCKETS bucket (smallest buffer).  ``resident=True`` runs the
    whole compress as device-only computation (``lax.switch`` over the
    static buckets) and is safe to call inside an enclosing ``jax.jit`` —
    the payload is padded to the worst-case capacity but every byte count
    and the valid prefix are identical.  ``donate=True`` (resident only)
    donates ``x``'s buffer to the computation.
    """
    backend = ops.resolve_backend(backend)
    if resident:
        with obs.span("compress.resident", pipeline="szp", backend=backend):
            if donate:
                with _quiet_donation():
                    parts = _compress_resident_donated(x, eb, block=block,
                                                       backend=backend)
            else:
                parts = _compress_resident_jit(x, eb, block=block,
                                               backend=backend)
        _obs_stream(parts, "szp", "resident")
        return parts
    with obs.span("compress.quant", pipeline="szp", backend=backend):
        first, mags, signs, widths, w_max = _quant_stage(x, eb, block,
                                                         backend)
        mw = bitpack.width_bucket(int(w_max))   # the existing sync point
    with obs.span("compress.pack", pipeline="szp", width_bucket=mw):
        parts = _pack_stage(first, mags, signs, widths, mw, backend)
    _obs_stream(parts, "szp", "classic")
    obs.counter_add(f"szp.compress.bucket_{mw}", 1)
    return parts


@functools.partial(jax.jit,
                   static_argnames=("n", "block", "recon", "backend"))
def _dequant_stage(parts: SZpParts, n: int, eb: float, block: int,
                   recon: str, backend: str) -> jnp.ndarray:
    """BE^ -> LZ^+B^ -> QZ^ through kernels.ops -> (n,) float32."""
    with jax.named_scope("szp.stage_restore"):
        mags, signs, _ = _unpack_sections(parts, block)
        out = ops.szp_dequant(parts.first, mags, signs[:, 1:], eb,
                              backend=backend)
        if recon == "left":
            out = out - eb
        elif recon != "center":
            raise ValueError(f"unknown recon mode: {recon}")
        return out.reshape(-1)[:n]


def tri_guard_width(block: int) -> int:
    """Smallest block width whose deltas can overflow the 2^24 tri-matmul
    exactness limit — the static threshold of the device-side dequant
    guard (``w_max >= tri_guard_width(block)`` <=> the host-side
    :func:`_dequant_backend_for` check)."""
    for w in range(bitpack.MAX_WIDTH + 1):
        if (block - 1) * ((1 << min(w, 31)) - 1) >= TRI_DEQUANT_EXACT:
            return w
    return bitpack.MAX_WIDTH + 1


@functools.partial(jax.jit,
                   static_argnames=("n", "block", "recon", "backend"))
def _dequant_guarded(parts: SZpParts, n: int, eb, block: int,
                     recon: str, backend: str) -> jnp.ndarray:
    """Dequant behind the in-graph 2^24 guard: a ``lax.cond`` on the
    device-computed max width picks the exact int32-cumsum path when the
    tri-matmul could lose integer exactness — no host sync."""
    if backend == "jnp":
        return _dequant_stage(parts, n, eb, block, recon, "jnp")
    overflow = parts.widths.astype(jnp.int32).max() >= tri_guard_width(block)
    return jax.lax.cond(
        overflow,
        lambda p: _dequant_stage(p, n, eb, block, recon, "jnp"),
        lambda p: _dequant_stage(p, n, eb, block, recon, backend),
        parts)


def szp_decompress(parts: SZpParts, shape: Sequence[int], eb,
                   block: int = DEFAULT_BLOCK, recon: str = "center",
                   backend: Optional[str] = None) -> jnp.ndarray:
    """Full SZp decompression back to a float field of ``shape``.

    Device-resident: the 2^24 dequant-exactness guard runs as an in-graph
    ``lax.cond``, so the call never syncs to the host and composes under
    an enclosing ``jax.jit``."""
    backend = ops.resolve_backend(backend)
    n = 1
    for s in shape:
        n *= s
    with obs.span("decompress.restore", pipeline="szp", backend=backend):
        out = _dequant_guarded(parts, n, eb, block, recon, backend)
    obs.counter_add("szp.decompress.calls", 1)
    return out.reshape(shape)


def _dequant_backend_for(parts: SZpParts, block: int, backend: str) -> str:
    """Resolved dequant backend after the 2^24 exactness guard (host-side
    form, one blocking width read; the jit paths use
    :func:`_dequant_guarded` instead)."""
    if backend == "jnp":
        return backend
    w_max = int(np.asarray(parts.widths).max(initial=0))
    max_delta = (1 << min(w_max, 31)) - 1
    if (block - 1) * max_delta >= TRI_DEQUANT_EXACT:
        return "jnp"                    # int32-cumsum fallback (exact)
    return backend


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _quant_stage_batch(xs: jnp.ndarray, eb: float, block: int, backend: str):
    """Batched pass 1; the width max is reduced over the WHOLE batch
    in-graph, so the caller's bucket decision reads one device scalar
    instead of N per-field maxes."""
    first, mags, signs, widths, w_max = jax.vmap(
        lambda x: _quant_stage(x, eb, block, backend))(xs)
    return first, mags, signs, widths, w_max.max()


@functools.partial(jax.jit, static_argnames=("max_width", "backend"))
def _pack_stage_batch(first, mags, signs, widths, max_width: int,
                      backend: str) -> SZpParts:
    return jax.vmap(lambda f, m, s, w: _assemble_parts(
        f, m, s, w, max_width, backend=backend))(first, mags, signs, widths)


def _compress_resident_batch(xs: jnp.ndarray, eb, block: int,
                             backend: str) -> SZpParts:
    """Batched device-resident compress: the bucket switch sits OUTSIDE
    the vmap (one shared bucket for the whole batch, same semantics as the
    classic batched pack), so it stays a real branch instead of a
    both-sides ``select``."""
    first, mags, signs, widths, _ = jax.vmap(
        lambda x: _quant_stage(x, eb, block, backend))(xs)
    (parts,) = _pack_switch(((first, mags, signs, widths),), block, backend,
                            batched=True)
    return parts


_compress_resident_batch_jit = jax.jit(
    _compress_resident_batch, static_argnames=("block", "backend"))
_compress_resident_batch_donated = jax.jit(
    _compress_resident_batch, static_argnames=("block", "backend"),
    donate_argnums=(0,))


def szp_compress_batch(xs: jnp.ndarray, eb,
                       block: int = DEFAULT_BLOCK,
                       backend: Optional[str] = None, resident: bool = False,
                       donate: bool = False) -> SZpParts:
    """Compress N stacked same-shape fields in one compiled call; every
    array of the result carries a leading batch axis.  Streams are
    byte-identical to N :func:`szp_compress` calls (the shared capacity
    bucket covers the batch max width; valid bytes are unaffected).

    ``resident=True`` keeps the whole batch on device (``lax.switch``
    bucket select, worst-case payload capacity, zero host syncs);
    ``donate=True`` (resident only) donates the stacked input buffer."""
    if xs.ndim < 2:
        raise ValueError(f"expected (N, ...) stacked fields, got {xs.shape}")
    backend = ops.resolve_backend(backend)
    if resident:
        with obs.span("compress.resident", pipeline="szp", backend=backend,
                      batch=xs.shape[0]):
            if donate:
                with _quiet_donation():
                    parts = _compress_resident_batch_donated(
                        xs, eb, block=block, backend=backend)
            else:
                parts = _compress_resident_batch_jit(xs, eb, block=block,
                                                     backend=backend)
        _obs_stream(parts, "szp", "resident")
        return parts
    with obs.span("compress.quant", pipeline="szp", backend=backend,
                  batch=xs.shape[0]):
        first, mags, signs, widths, w_max = _quant_stage_batch(
            xs, eb, block=block, backend=backend)
        mw = bitpack.width_bucket(int(w_max))
    with obs.span("compress.pack", pipeline="szp", width_bucket=mw):
        parts = _pack_stage_batch(first, mags, signs, widths, max_width=mw,
                                  backend=backend)
    _obs_stream(parts, "szp", "classic")
    obs.counter_add(f"szp.compress.bucket_{mw}", xs.shape[0])
    return parts


@functools.partial(jax.jit,
                   static_argnames=("n", "block", "recon", "backend"))
def _dequant_stage_batch(parts: SZpParts, n: int, eb: float, block: int,
                         recon: str, backend: str) -> jnp.ndarray:
    return jax.vmap(
        lambda p: _dequant_stage(p, n, eb, block, recon, backend))(parts)


@functools.partial(jax.jit,
                   static_argnames=("n", "block", "recon", "backend"))
def _dequant_guarded_batch(parts: SZpParts, n: int, eb, block: int,
                           recon: str, backend: str) -> jnp.ndarray:
    """Batched guarded dequant: the 2^24 ``lax.cond`` is hoisted OUTSIDE
    the vmap (scalar max over the whole batch's widths) — under vmap a
    cond would lower to ``select`` and execute both branches."""
    if backend == "jnp":
        return _dequant_stage_batch(parts, n, eb, block, recon, "jnp")
    overflow = parts.widths.astype(jnp.int32).max() >= tri_guard_width(block)
    return jax.lax.cond(
        overflow,
        lambda p: _dequant_stage_batch(p, n, eb, block, recon, "jnp"),
        lambda p: _dequant_stage_batch(p, n, eb, block, recon, backend),
        parts)


def szp_decompress_batch(parts: SZpParts, shape: Sequence[int], eb,
                         block: int = DEFAULT_BLOCK, recon: str = "center",
                         backend: Optional[str] = None) -> jnp.ndarray:
    """Decompress a batched stream -> (N, *shape); equal to stacking N
    per-field :func:`szp_decompress` calls.  Device-resident (in-graph
    dequant guard, no host syncs)."""
    backend = ops.resolve_backend(backend)
    n = 1
    for s in shape:
        n *= s
    with obs.span("decompress.restore", pipeline="szp", backend=backend,
                  batch=parts.widths.shape[0]):
        out = _dequant_guarded_batch(parts, n=n, eb=eb, block=block,
                                     recon=recon, backend=backend)
    obs.counter_add("szp.decompress.calls", parts.widths.shape[0])
    return out.reshape((parts.widths.shape[0],) + tuple(shape))


def szp_roundtrip(x: jnp.ndarray, eb: float, block: int = DEFAULT_BLOCK,
                  backend: Optional[str] = None
                  ) -> Tuple[jnp.ndarray, SZpParts]:
    parts = szp_compress(x, eb, block=block, backend=backend)
    return szp_decompress(parts, tuple(x.shape), eb, block=block,
                          backend=backend), parts


def compression_ratio(x: jnp.ndarray, parts: SZpParts) -> jnp.ndarray:
    raw = x.size * x.dtype.itemsize
    return raw / parts.nbytes.astype(jnp.float32)


def num_blocks(n: int, block: int = DEFAULT_BLOCK) -> int:
    return cdiv(n, block)
