"""SZp linear quantizer (paper Sec. II-C).

The paper defines the encoder  q_a = floor((a + eps) / (2 eps))  which equals
round-half-up of a / (2 eps).  Values in the half-open bin
[2 eps q - eps, 2 eps q + eps) share the index q.

Reconstruction: the paper prints  a_hat = q * 2 eps - eps  and calls it the
bin *center*; the true center of the bin above is  2 eps q  (the printed
formula is the left edge and only bounds the error by 2 eps).  We default to
the center so the claimed |a_hat - a| <= eps holds strictly; the paper's
literal formula is available via recon="left" for ablation.  See DESIGN.md
"Paper-faithfulness notes".

Both the encoder and the decoder are monotone non-decreasing, which is the
property behind the paper's FP = FT = 0 guarantee (Sec. III-B).
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize(x: jnp.ndarray, eb: float) -> jnp.ndarray:
    """Quantize floats to int32 bin indices under absolute error bound eb."""
    x = x.astype(jnp.float32)
    # floor((a + eb) / (2 eb)) -- paper formula, == round-half-up(a / 2eb).
    return jnp.floor((x + eb) / (2.0 * eb)).astype(jnp.int32)


def dequantize(q: jnp.ndarray, eb: float, recon: str = "center") -> jnp.ndarray:
    """Map bin indices back to representative values.

    recon="center": a_hat = 2 eb q      (|a_hat - a| <= eb, default)
    recon="left":   a_hat = 2 eb q - eb (paper's literal formula; <= 2 eb)
    """
    a = q.astype(jnp.float32) * (2.0 * eb)
    if recon == "left":
        a = a - eb
    elif recon != "center":
        raise ValueError(f"unknown recon mode: {recon}")
    return a


def quantize_roundtrip(x: jnp.ndarray, eb: float, recon: str = "center") -> jnp.ndarray:
    """Quantize + dequantize (the lossy identity SZp applies to every value)."""
    return dequantize(quantize(x, eb), eb, recon=recon)
