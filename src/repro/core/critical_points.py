"""Critical point detection (paper Sec. IV-A, "CD" stage).

Each grid point of a 2-D scalar field is classified against its 4-neighbors
(top/bottom/left/right) into:

  REGULAR = 0 (00)   MINIMA = 1 (01)   SADDLE = 2 (10)   MAXIMA = 3 (11)

using *strict* comparisons.  Corner points use two neighbors and edge points
three (paper); a saddle requires both opposite pairs, so saddles are only
defined at interior points (a 3-neighbor "saddle" is ill-posed on the
4-neighborhood — documented choice).

The classification is branch-free (comparison masks) and is the oracle for
the Pallas kernel in kernels/cp_detect.py.
"""
from __future__ import annotations

import jax.numpy as jnp

REGULAR, MINIMA, SADDLE, MAXIMA = 0, 1, 2, 3
LABEL_NAMES = {REGULAR: "regular", MINIMA: "minima", SADDLE: "saddle", MAXIMA: "maxima"}


def _shifted(field: jnp.ndarray):
    """Return (value, exists) for the t/d/l/r neighbors of every point."""
    ny, nx = field.shape
    pad = jnp.pad(field, 1, mode="edge")
    t = pad[:-2, 1:-1]
    d = pad[2:, 1:-1]
    l = pad[1:-1, :-2]
    r = pad[1:-1, 2:]
    ii = jnp.arange(ny)[:, None]
    jj = jnp.arange(nx)[None, :]
    has_t = (ii > 0) & jnp.ones((1, nx), bool)
    has_d = (ii < ny - 1) & jnp.ones((1, nx), bool)
    has_l = jnp.ones((ny, 1), bool) & (jj > 0)
    has_r = jnp.ones((ny, 1), bool) & (jj < nx - 1)
    return (t, has_t), (d, has_d), (l, has_l), (r, has_r)


def classify(field: jnp.ndarray) -> jnp.ndarray:
    """Label map for a 2-D field -> int32 (ny, nx) in {0,1,2,3}."""
    f = field.astype(jnp.float32)
    (t, ht), (d, hd), (l, hl), (r, hr) = _shifted(f)

    # per-direction strict comparisons; a missing neighbor never vetoes.
    hi_t = jnp.where(ht, t > f, True)   # neighbor strictly higher (or absent)
    hi_d = jnp.where(hd, d > f, True)
    hi_l = jnp.where(hl, l > f, True)
    hi_r = jnp.where(hr, r > f, True)
    lo_t = jnp.where(ht, t < f, True)
    lo_d = jnp.where(hd, d < f, True)
    lo_l = jnp.where(hl, l < f, True)
    lo_r = jnp.where(hr, r < f, True)

    is_min = hi_t & hi_d & hi_l & hi_r
    is_max = lo_t & lo_d & lo_l & lo_r

    interior = ht & hd & hl & hr
    vert_hi = (t > f) & (d > f)
    vert_lo = (t < f) & (d < f)
    horz_hi = (l > f) & (r > f)
    horz_lo = (l < f) & (r < f)
    is_saddle = interior & ((vert_hi & horz_lo) | (vert_lo & horz_hi))

    labels = jnp.where(is_min, MINIMA, REGULAR)
    labels = jnp.where(is_saddle, SADDLE, labels)
    labels = jnp.where(is_max, MAXIMA, labels)
    return labels.astype(jnp.int32)


def neighbor_min_max(field: jnp.ndarray):
    """(min, max) over *available* 4-neighbors of each point (edge-aware)."""
    f = field.astype(jnp.float32)
    (t, ht), (d, hd), (l, hl), (r, hr) = _shifted(f)
    big = jnp.float32(jnp.inf)
    nmin = jnp.minimum(
        jnp.minimum(jnp.where(ht, t, big), jnp.where(hd, d, big)),
        jnp.minimum(jnp.where(hl, l, big), jnp.where(hr, r, big)))
    nmax = jnp.maximum(
        jnp.maximum(jnp.where(ht, t, -big), jnp.where(hd, d, -big)),
        jnp.maximum(jnp.where(hl, l, -big), jnp.where(hr, r, -big)))
    return nmin, nmax


def count_labels(labels: jnp.ndarray):
    """Dict of counts per class (host-friendly)."""
    return {name: int((labels == code).sum())
            for code, name in LABEL_NAMES.items()}
