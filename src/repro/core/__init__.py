"""TopoSZp core: the paper's contribution as composable JAX modules."""
from repro.core.quantize import quantize, dequantize, quantize_roundtrip
from repro.core.critical_points import (classify, REGULAR, MINIMA, SADDLE,
                                        MAXIMA)
from repro.core.szp import (szp_compress, szp_decompress, szp_roundtrip,
                            SZpParts, DEFAULT_BLOCK)
from repro.core.toposzp import (toposzp_compress, toposzp_decompress,
                                toposzp_roundtrip, TopoSZpCompressed,
                                pages_as_fields, fields_as_pages)
from repro.core.metrics import (false_cases, false_cases_host, psnr,
                                max_abs_error, bitrate, compression_ratio)

__all__ = [
    "quantize", "dequantize", "quantize_roundtrip",
    "classify", "REGULAR", "MINIMA", "SADDLE", "MAXIMA",
    "szp_compress", "szp_decompress", "szp_roundtrip", "SZpParts",
    "DEFAULT_BLOCK",
    "toposzp_compress", "toposzp_decompress", "toposzp_roundtrip",
    "TopoSZpCompressed", "pages_as_fields", "fields_as_pages",
    "false_cases", "false_cases_host", "psnr", "max_abs_error", "bitrate",
    "compression_ratio",
]
