"""Evaluation metrics (paper Sec. V): FN/FP/FT counts, PSNR, bitrate, ratio."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.critical_points import REGULAR, classify


@jax.jit
def false_cases(orig: jnp.ndarray, recon: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Topological error counts between original and reconstructed fields.

    FN: true critical point became regular.
    FP: regular point became critical.
    FT: critical point changed critical type (m/s/M flip).
    """
    lo = classify(orig)
    lr = classify(recon)
    fn = (lo != REGULAR) & (lr == REGULAR)
    fp = (lo == REGULAR) & (lr != REGULAR)
    ft = (lo != REGULAR) & (lr != REGULAR) & (lo != lr)
    return {"FN": fn.sum(), "FP": fp.sum(), "FT": ft.sum(),
            "total": fn.sum() + fp.sum() + ft.sum(),
            "n_cp": (lo != REGULAR).sum()}


def false_cases_host(orig, recon) -> Dict[str, int]:
    return {k: int(v) for k, v in false_cases(orig, recon).items()}


@jax.jit
def max_abs_error(orig: jnp.ndarray, recon: jnp.ndarray) -> jnp.ndarray:
    return jnp.abs(orig.astype(jnp.float32) - recon.astype(jnp.float32)).max()


@jax.jit
def psnr(orig: jnp.ndarray, recon: jnp.ndarray) -> jnp.ndarray:
    o = orig.astype(jnp.float32)
    r = recon.astype(jnp.float32)
    mse = jnp.mean((o - r) ** 2)
    rng = jnp.maximum(o.max() - o.min(), 1e-30)
    return 20.0 * jnp.log10(rng) - 10.0 * jnp.log10(jnp.maximum(mse, 1e-38))


def bitrate(n_values: int, nbytes: int) -> float:
    """Average bits per value in the compressed stream (paper footnote 1)."""
    return 8.0 * float(nbytes) / float(n_values)


def compression_ratio(n_values: int, nbytes: int, itemsize: int = 4) -> float:
    return float(n_values) * itemsize / float(nbytes)
