"""Extrema restoration stencils (paper Sec. IV-B, "CP-hat + RP-hat" stage).

A minimum lost to quantization is pushed delta ULPs *below* the minimum of
its available neighbors; a lost maximum delta ULPs *above* the maximum
(delta = the stored same-bin rank).  "delta times machine epsilon" is
realized as delta steps in the monotone IEEE-754 integer ordering (exact,
deterministic — see DESIGN.md notes).

Corrections that would exceed the relaxed bound (|cand - recon_szp| <= eb,
hence |cand - orig| <= 2 eb) are skipped — the point stays an FN rather than
violating the bound (paper: "we deliberately avoid such situations").
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.critical_points import MAXIMA, MINIMA, classify, neighbor_min_max
from repro.kernels import ops
from repro.utils import ulp_step


def apply_extrema_stencils(recon: jnp.ndarray, labels: jnp.ndarray,
                           ranks: jnp.ndarray, eb: float,
                           backend: Optional[str] = None,
                           cur: Optional[jnp.ndarray] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Restore lost extrema on the SZp reconstruction.

    Args:
      recon:  (ny, nx) SZp-decompressed field (|recon - orig| <= eb).
      labels: (ny, nx) original CD labels from the stream.
      ranks:  (ny, nx) same-bin ranks from the stream (delta in the paper).
      eb:     the user error bound eps (correction budget is +-eb on top).
      backend: None keeps the legacy pure-jnp math; a kernels.ops backend
        dispatches the CP^ reclassification and the fused extrema stencil
        through the kernel suite (bit-identical to the jnp math).
      cur:    precomputed ``classify(recon)`` labels, if the caller has them.

    Returns:
      (corrected field, bool mask of applied corrections)
    """
    if backend is not None:
        return _apply_extrema_stencils_ops(recon, labels, ranks, eb,
                                           backend, cur)
    recon = recon.astype(jnp.float32)
    cur = classify(recon) if cur is None else cur
    is_min = labels == MINIMA
    is_max = labels == MAXIMA
    is_cp = labels != 0
    lost_min = is_min & (cur != MINIMA)
    lost_max = is_max & (cur != MAXIMA)

    nmin, nmax = neighbor_min_max(recon)
    delta = jnp.maximum(ranks, 1)
    tgt_min = ulp_step(nmin, -delta)          # strictly below all neighbors
    tgt_max = ulp_step(nmax, +delta)          # strictly above all neighbors

    # relaxed-but-strict bound: only apply if the target stays within
    # recon +- eb (=> total error <= 2 eb).
    ok_min = lost_min & (tgt_min >= recon - eb) & (tgt_min <= recon + eb)
    ok_max = lost_max & (tgt_max >= recon - eb) & (tgt_max <= recon + eb)

    out = jnp.where(ok_min, tgt_min, recon)
    out = jnp.where(ok_max, tgt_max, out)

    # RP separation for SURVIVING critical points (paper Sec. III-C /
    # Fig. 5): same-bin CPs reconstruct to the same center, erasing their
    # ordering; move each by its rank in ULPs (maxima/saddles up, minima
    # down — rank directions chosen in relative_order.py so this restores
    # the original order).  ULP-scale: never threatens the 2 eb bound.
    survive = is_cp & ~(ok_min | ok_max)
    sep = jnp.where(is_min, -delta, delta)
    out = jnp.where(survive, ulp_step(out, sep), out)
    return out, (ok_min | ok_max | survive)


def _apply_extrema_stencils_ops(recon, labels, ranks, eb: float,
                                backend: str, cur=None):
    """Kernel-dispatched form: the fused stencil (kernels/extrema_restore)
    restores lost extrema; the RP separation for surviving CPs rides on
    top.  An applied correction always moves the value (a lost minimum has
    nmin <= recon, so its target sits strictly below recon; dually for
    maxima), so ``ext != recon`` recovers the applied mask exactly."""
    recon = recon.astype(jnp.float32)
    cur = ops.cp_detect(recon, backend=backend) if cur is None else cur
    ext = ops.extrema_restore(recon, labels, cur, ranks, eb, backend=backend)
    applied = ext != recon
    is_cp = labels != 0
    delta = jnp.maximum(ranks, 1)
    survive = is_cp & ~applied
    sep = jnp.where(labels == MINIMA, -delta, delta)
    out = jnp.where(survive, ulp_step(ext, sep), ext)
    return out, (applied | survive)
