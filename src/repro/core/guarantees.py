"""FP/FT suppression and error-bound enforcement (paper Sec. IV-B, end).

The paper: "to prevent introducing false positives (FP) or false types (FT),
we track whether the refinement would generate a new or different type of
critical point not present in the original critical map; if so, we suppress
the correction".

Implementation: iteratively re-classify the corrected field; wherever a
point's new label is a critical type that differs from its original label
(FP: regular -> CP, FT: CP type flip), revert every correction in its
1-neighborhood and retry.  The corrected set shrinks monotonically, so the
loop terminates (empty set = plain SZp output, which is FP/FT-free by
monotonicity, Sec. III-B); in practice it converges in 1-2 iterations.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.critical_points import REGULAR, classify

_MAX_ITERS = 32


def _dilate(mask: jnp.ndarray) -> jnp.ndarray:
    """4-neighborhood dilation of a boolean mask (plus the mask itself)."""
    p = jnp.pad(mask, 1, mode="constant", constant_values=False)
    return (mask | p[:-2, 1:-1] | p[2:, 1:-1] | p[1:-1, :-2] | p[1:-1, 2:])


def violations(field: jnp.ndarray, labels_orig: jnp.ndarray) -> jnp.ndarray:
    """Mask of FP or FT points w.r.t. the original label map."""
    lbl = classify(field)
    return (lbl != REGULAR) & (lbl != labels_orig)


@partial(jax.jit, donate_argnums=())
def enforce_no_fp_ft(base: jnp.ndarray, cand: jnp.ndarray,
                     labels_orig: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Suppress corrections until the field has zero FP and zero FT.

    Args:
      base:        plain SZp reconstruction (guaranteed FP/FT-free).
      cand:        candidate field = base + stencil/RBF corrections.
      labels_orig: original CD label map from the stream.

    Returns:
      (final field, surviving-correction mask)
    """
    base = base.astype(jnp.float32)
    cand = cand.astype(jnp.float32)
    keep0 = cand != base

    def cond(state):
        keep, it = state
        field = jnp.where(keep, cand, base)
        viol = violations(field, labels_orig)
        return jnp.any(viol) & (it < _MAX_ITERS)

    def body(state):
        keep, it = state
        field = jnp.where(keep, cand, base)
        viol = violations(field, labels_orig)
        keep = keep & ~_dilate(viol)
        return keep, it + 1

    keep, _ = jax.lax.while_loop(cond, body, (keep0, jnp.int32(0)))
    return jnp.where(keep, cand, base), keep


def enforce_error_bound(base: jnp.ndarray, cand: jnp.ndarray,
                        eb: float) -> jnp.ndarray:
    """Hard clamp: |out - base| <= eb, hence |out - orig| <= 2 eb."""
    return jnp.clip(cand, base - eb, base + eb)
