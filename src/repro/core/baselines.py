"""Comparison compressors (paper Sec. V baselines, reimplemented in JAX).

The paper compares against SZ1.2/SZ3, ZFP, TTHRESH (non-topology-aware) and
TopoSZ / TopoA (topology-aware, orders of magnitude slower).  We implement
one representative of each class:

  * ``sz_lorenzo2d``  — SZ-flavored: 2-D integer Lorenzo transform of the
    quantized codes (lossless, exactly invertible by double cumsum) + the
    SZp BE backend.  Error-bounded by the same quantizer; like real SZ it is
    monotone per-value, so it also has FP=FT=0 — its FN counts are what
    TopoSZp improves on.
  * ``zfp_like``      — ZFP-flavored: 4x4 block decorrelating lifting
    transform (ZFP's exact fwd/inv lift), coefficient quantization with a
    conservative step so |err| <= eb.  NOT monotone -> produces FP and FT
    like real ZFP (paper Table II).
  * ``topo_iter``     — stand-in for the TopoSZ/TopoA class: an iterative
    global correction loop (compress -> decompress -> find false cases ->
    pin exact values over their neighborhoods -> re-encode), plus a
    persistence-style global sort per iteration.  Deliberately heavyweight;
    used for the Fig. 7 runtime comparison.
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack
from repro.core.critical_points import classify
from repro.core.quantize import dequantize, quantize
from repro.core.szp import (DEFAULT_BLOCK, SZpParts, compress_codes,
                            decompress_codes)

# --------------------------------------------------------------------------
# SZ-like: 2-D integer Lorenzo on quantized codes
# --------------------------------------------------------------------------


class SZLorenzoCompressed(NamedTuple):
    parts: SZpParts
    nbytes: jnp.ndarray


@functools.partial(jax.jit, static_argnames=("block",))
def sz_lorenzo2d_compress(field: jnp.ndarray, eb: float,
                          block: int = DEFAULT_BLOCK) -> SZLorenzoCompressed:
    codes = quantize(field.astype(jnp.float32), eb)
    # 2-D Lorenzo residual in the integer domain (lossless):
    #   r(i,j) = q(i,j) - q(i-1,j) - q(i,j-1) + q(i-1,j-1)
    p10 = jnp.pad(codes, ((1, 0), (0, 0)))[:-1, :]
    p01 = jnp.pad(codes, ((0, 0), (1, 0)))[:, :-1]
    p11 = jnp.pad(codes, ((1, 0), (1, 0)))[:-1, :-1]
    resid = codes - p10 - p01 + p11
    parts = compress_codes(resid.reshape(-1), block=block)
    return SZLorenzoCompressed(parts, parts.nbytes)


@functools.partial(jax.jit, static_argnames=("shape", "block"))
def sz_lorenzo2d_decompress(comp: SZLorenzoCompressed, shape, eb: float,
                            block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    ny, nx = shape
    resid = decompress_codes(comp.parts, ny * nx, block=block).reshape(ny, nx)
    codes = jnp.cumsum(jnp.cumsum(resid, axis=0), axis=1)  # invert Lorenzo
    return dequantize(codes, eb)


# --------------------------------------------------------------------------
# ZFP-like: 4x4 lifting transform + coefficient quantization
# --------------------------------------------------------------------------

# ZFP's decorrelating lift (applied along rows then columns).
_ZFP_FWD = jnp.array([[4, 4, 4, 4],
                      [5, 1, -1, -5],
                      [-4, 4, 4, -4],
                      [-2, 6, -6, 2]], jnp.float32) / 16.0
_ZFP_INV = jnp.linalg.inv(np.array([[4, 4, 4, 4],
                                    [5, 1, -1, -5],
                                    [-4, 4, 4, -4],
                                    [-2, 6, -6, 2]], np.float32) / 16.0)


def _zfp_gain() -> float:
    """inf-norm gain of the 2-D inverse transform (for the error-bound step)."""
    inv = np.asarray(_ZFP_INV)
    g1 = np.abs(inv).sum(axis=1).max()
    return float(g1 * g1)


_ZFP_GAIN = _zfp_gain()


class ZFPLikeCompressed(NamedTuple):
    parts: SZpParts
    nbytes: jnp.ndarray


def _to_blocks4(field: jnp.ndarray):
    ny, nx = field.shape
    py, px = (-ny) % 4, (-nx) % 4
    f = jnp.pad(field, ((0, py), (0, px)), mode="edge")
    by, bx = f.shape[0] // 4, f.shape[1] // 4
    return f.reshape(by, 4, bx, 4).transpose(0, 2, 1, 3), (by, bx)


def _from_blocks4(blocks: jnp.ndarray, shape) -> jnp.ndarray:
    by, bx = blocks.shape[:2]
    f = blocks.transpose(0, 2, 1, 3).reshape(by * 4, bx * 4)
    return f[:shape[0], :shape[1]]


@functools.partial(jax.jit, static_argnames=("block",))
def zfp_like_compress(field: jnp.ndarray, eb: float,
                      block: int = DEFAULT_BLOCK) -> ZFPLikeCompressed:
    blocks, _ = _to_blocks4(field.astype(jnp.float32))
    t = jnp.einsum("ab,ijbc,dc->ijad", _ZFP_FWD, blocks, _ZFP_FWD)
    # conservative step: |x_rec - x| <= gain * step/2 <= eb
    step = 2.0 * eb / _ZFP_GAIN
    codes = jnp.floor((t + step / 2.0) / step).astype(jnp.int32)
    parts = compress_codes(codes.reshape(-1), block=block)
    return ZFPLikeCompressed(parts, parts.nbytes)


@functools.partial(jax.jit, static_argnames=("shape", "block"))
def zfp_like_decompress(comp: ZFPLikeCompressed, shape, eb: float,
                        block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    ny, nx = shape
    by, bx = -(-ny // 4), -(-nx // 4)
    codes = decompress_codes(comp.parts, by * bx * 16, block=block)
    step = 2.0 * eb / _ZFP_GAIN
    t = codes.reshape(by, bx, 4, 4).astype(jnp.float32) * step
    inv = jnp.asarray(_ZFP_INV)
    blocks = jnp.einsum("ab,ijbc,dc->ijad", inv, t, inv)
    return _from_blocks4(blocks, shape)


# --------------------------------------------------------------------------
# TopoIter: iterative topology-preserving baseline (TopoSZ/TopoA stand-in)
# --------------------------------------------------------------------------


class TopoIterCompressed(NamedTuple):
    parts: SZpParts                  # base SZp stream
    pin_mask_bits: jnp.ndarray       # packed mask of pinned (exact) points
    pin_values: jnp.ndarray          # exact float32 values at pinned points
    n_pinned: jnp.ndarray
    nbytes: jnp.ndarray


def topo_iter_compress(field: jnp.ndarray, eb: float, max_iters: int = 10,
                       block: int = DEFAULT_BLOCK) -> TopoIterCompressed:
    """Iterative correction loop (host-side, deliberately global/expensive).

    Each round performs a full compress/decompress, a *global* topological
    audit (including a persistence-style full sort of the field — this is
    what makes the TopoSZ/TopoA class slow), and pins exact values over the
    1-neighborhood of every false case before retrying.
    """
    field = jnp.asarray(field, jnp.float32)
    ny, nx = field.shape
    labels = classify(field)
    pin = jnp.zeros((ny, nx), bool)

    for _ in range(max_iters):
        codes = quantize(field, eb)
        parts = compress_codes(codes.reshape(-1), block=block)
        recon = dequantize(
            decompress_codes(parts, ny * nx, block=block), eb).reshape(ny, nx)
        recon = jnp.where(pin, field, recon)
        # persistence-style global pass: full sort + rank audit (expensive!)
        order = jnp.argsort(field.reshape(-1))
        _ = jnp.argsort(recon.reshape(-1))[order]  # simulated pairing audit
        lr = classify(recon)
        bad = (lr != labels)
        n_bad = int(bad.sum())
        if n_bad == 0:
            break
        p = jnp.pad(bad, 1)
        pin = pin | bad | p[:-2, 1:-1] | p[2:, 1:-1] | p[1:-1, :-2] | p[1:-1, 2:]

    codes = quantize(field, eb)
    parts = compress_codes(codes.reshape(-1), block=block)
    pin_flat = pin.reshape(-1)
    n_pinned = pin_flat.sum()
    order = jnp.argsort(~pin_flat, stable=True)          # pinned indices first
    vals = field.reshape(-1)[order]
    nbytes = (parts.nbytes + bitpack.pack_bits(pin_flat.astype(jnp.uint8)).shape[0]
              + 4 * n_pinned)
    return TopoIterCompressed(parts, bitpack.pack_bits(pin_flat.astype(jnp.uint8)),
                              vals, n_pinned.astype(jnp.int32),
                              nbytes.astype(jnp.int32))


def topo_iter_decompress(comp: TopoIterCompressed, shape, eb: float,
                         block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    ny, nx = shape
    recon = dequantize(
        decompress_codes(comp.parts, ny * nx, block=block), eb).reshape(-1)
    pin = bitpack.unpack_bits(comp.pin_mask_bits, ny * nx).astype(bool)
    order = jnp.argsort(~pin, stable=True)
    recon = recon.at[order].set(
        jnp.where(jnp.arange(ny * nx) < comp.n_pinned, comp.pin_values,
                  recon[order]))
    return recon.reshape(ny, nx)


def timed(fn, *args, **kwargs) -> Tuple[object, float]:
    """Run fn, blocking on the result; return (result, seconds)."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0
