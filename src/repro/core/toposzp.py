"""TopoSZp: the full topology-aware compression pipeline (paper Sec. IV).

Compression  :  CD + RP  ->  QZ  ->  B + LZ  ->  BE        (Sec. IV-A)
Decompression:  BE^ -> LZ^+B^ -> QZ^ -> MD^ -> CP^+RP^ -> RS^  (Sec. IV-B)

Stream layout = SZp sections (1)-(5) plus (6) the 2-bit critical-point label
map and (7) the relative-order metadata, itself re-compressed with a second
lossless B+LZ+BE pass (paper Fig. 6).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.critical_points import classify
from repro.core.guarantees import enforce_no_fp_ft
from repro.core.quantize import dequantize, quantize
from repro.core.rbf import refine_saddles
from repro.core.relative_order import compute_ranks
from repro.core.stencils import apply_extrema_stencils
from repro.core.szp import (DEFAULT_BLOCK, HEADER_BYTES, SZpParts,
                            compress_codes, decompress_codes)


class TopoSZpCompressed(NamedTuple):
    """Full TopoSZp stream: SZp sections + topology metadata sections."""
    szp: SZpParts                # sections (1)-(5)
    labels2b: jnp.ndarray        # section (6): packed 2-bit label map
    ranks: SZpParts              # section (7): lossless B+LZ+BE over ranks
    n_cp: jnp.ndarray            # () int32 critical point count
    nbytes: jnp.ndarray          # () int32 total compressed size


def _cp_first_order(labels_flat: jnp.ndarray) -> jnp.ndarray:
    """Stable permutation putting critical points first (row-major order).

    Beyond-paper ratio optimization (§Perf/compression): ranks are stored
    only for the n_cp critical points instead of densely — the decompressor
    recovers positions from the label map, so only ceil(n_cp/block) blocks
    of the rank stream carry data and the accounting/serialization slices
    the stream there.
    """
    return jnp.argsort((labels_flat == 0).astype(jnp.int32), stable=True)


def rank_stream_bytes(n_cp: jnp.ndarray, payload_nbytes: jnp.ndarray,
                      block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Size of the sparse rank section: only the used block prefix."""
    ub = (n_cp + block - 1) // block
    return (HEADER_BYTES + (ub + 7) // 8 + ub + (block * ub + 7) // 8
            + 4 * ub + payload_nbytes).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def toposzp_compress(field: jnp.ndarray, eb: float,
                     block: int = DEFAULT_BLOCK) -> TopoSZpCompressed:
    """Compress a 2-D scalar field with topology metadata."""
    field = field.astype(jnp.float32)
    codes = quantize(field, eb)

    # --- CD + RP (the lightweight topology stage, before lossy QZ) ---
    labels = classify(field)
    ranks = compute_ranks(field, labels, codes)

    # --- QZ -> B+LZ -> BE (standard SZp on the codes) ---
    szp_parts = compress_codes(codes.reshape(-1), block=block)

    # --- metadata sections ---
    labels_flat = labels.reshape(-1)
    labels2b = bitpack.pack_2bit(labels_flat)
    n_cp = (labels_flat != 0).sum().astype(jnp.int32)
    order = _cp_first_order(labels_flat)
    ranks_sorted = ranks.reshape(-1)[order]       # CP ranks first, zeros after
    rank_parts = compress_codes(ranks_sorted, block=block)   # lossless

    nbytes = (szp_parts.nbytes + labels2b.shape[0]
              + rank_stream_bytes(n_cp, rank_parts.payload_nbytes, block))
    return TopoSZpCompressed(szp_parts, labels2b, rank_parts, n_cp,
                             nbytes.astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("shape", "block", "rbf_mode", "recon"))
def toposzp_decompress(comp: TopoSZpCompressed, shape: Sequence[int], eb: float,
                       block: int = DEFAULT_BLOCK, rbf_mode: str = "shepard",
                       recon: str = "center") -> jnp.ndarray:
    """Decompress with extrema restoration + RBF saddle refinement.

    Guarantees on the output (tested in tests/test_toposzp_guarantees.py):
      * |out - orig| <= 2 eb (relaxed-but-strict bound, paper Table I)
      * zero FP, zero FT w.r.t. the original label map
    """
    ny, nx = shape
    n = ny * nx

    # --- BE^ -> LZ^ + B^ -> QZ^ (standard SZp reconstruction) ---
    codes = decompress_codes(comp.szp, n, block=block)
    base = dequantize(codes, eb, recon=recon).reshape(shape)

    # --- MD^: metadata extraction ---
    labels = bitpack.unpack_2bit(comp.labels2b, n).reshape(shape)
    labels_flat = labels.reshape(-1)
    # sparse rank stream: CP-first order; the stream may be trimmed to its
    # used prefix (deserialization), so decode its actual block count.
    n_codes = comp.ranks.widths.shape[0] * block
    ranks_sorted = decompress_codes(comp.ranks, min(n_codes, n), block=block)
    if n_codes < n:
        ranks_sorted = jnp.concatenate(
            [ranks_sorted, jnp.zeros(n - n_codes, jnp.int32)])
    order = _cp_first_order(labels_flat)
    ranks = jnp.zeros(n, jnp.int32).at[order].set(
        ranks_sorted[:n]).reshape(shape)

    # --- CP^ + RP^: extrema stencils with same-bin rank separation ---
    ext, _ = apply_extrema_stencils(base, labels, ranks, eb)

    # --- RS^: RBF refinement of lost saddles ---
    ref, _ = refine_saddles(ext, labels, eb, rbf_mode=rbf_mode)

    # --- FP/FT suppression (zero false positives / false types) ---
    out, _ = enforce_no_fp_ft(base, ref, labels)
    return out


def toposzp_roundtrip(field: jnp.ndarray, eb: float,
                      block: int = DEFAULT_BLOCK,
                      rbf_mode: str = "shepard"
                      ) -> Tuple[jnp.ndarray, TopoSZpCompressed]:
    comp = toposzp_compress(field, eb, block=block)
    out = toposzp_decompress(comp, tuple(field.shape), eb, block=block,
                             rbf_mode=rbf_mode)
    return out, comp
