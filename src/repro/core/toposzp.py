"""TopoSZp: the full topology-aware compression pipeline (paper Sec. IV).

Compression  :  CD + RP  ->  QZ  ->  B + LZ  ->  BE        (Sec. IV-A)
Decompression:  BE^ -> LZ^+B^ -> QZ^ -> MD^ -> CP^+RP^ -> RS^  (Sec. IV-B)

Stream layout = SZp sections (1)-(5) plus (6) the 2-bit critical-point label
map and (7) the relative-order metadata, itself re-compressed with a second
lossless B+LZ+BE pass (paper Fig. 6).

Every stage dispatches through ``kernels.ops`` (``backend={"pallas",
"interpret","jnp"}``; ``None`` resolves to the hardware default): CD via
``cp_detect``, QZ+LZ via the fused ``szp_quant``, BE via the tiled
two-pass pack (static capacity = measured width bucket, see core/szp.py),
QZ^ via ``szp_dequant`` behind the |code|<2^24 tri-matmul guard, CP^+RP^
via ``extrema_restore`` and RS^ via the separable ``shepard_refine``.
Stream bytes are bit-identical across all backends.  The rank stream
(section 7) must stay lossless, so its decode always takes the exact
int32-cumsum path regardless of backend.

``toposzp_compress_batch`` / ``toposzp_decompress_batch`` stack N
same-shape fields into ONE compiled call (vmap = grid over the batch dim),
so multi-field workloads (checkpoint shards, the fig7 bench) stop paying a
dispatch + trace per field.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitpack
from repro.core.guarantees import enforce_no_fp_ft
from repro.core.quantize import quantize
from repro.core.rbf import refine_saddles
from repro.core.relative_order import compute_ranks
from repro.core.stencils import apply_extrema_stencils
from repro.core.szp import (DEFAULT_BLOCK, HEADER_BYTES, SZpParts,
                            _assemble_parts, _blocked_codes, _blocked_field,
                            _delta_blocks, _pack_switch, _quiet_donation,
                            _unpack_sections, decompress_codes,
                            tri_guard_width)
from repro.kernels import ops


class TopoSZpCompressed(NamedTuple):
    """Full TopoSZp stream: SZp sections + topology metadata sections.

    The batched APIs use the same container with a leading batch axis on
    every array (``batch_slice`` recovers the per-field view).
    """
    szp: SZpParts                # sections (1)-(5)
    labels2b: jnp.ndarray        # section (6): packed 2-bit label map
    ranks: SZpParts              # section (7): lossless B+LZ+BE over ranks
    n_cp: jnp.ndarray            # () int32 critical point count
    nbytes: jnp.ndarray          # () int32 total compressed size


def _cp_first_dest(labels_flat: jnp.ndarray) -> jnp.ndarray:
    """Destination index of every point under the stable CP-first partition.

    Equivalent to inverting ``argsort(labels == 0, stable)`` but realized
    as two prefix sums + a select — O(n) instead of a full sort on the
    decompression AND compression hot paths.

    Beyond-paper ratio optimization (§Perf/compression): ranks are stored
    only for the n_cp critical points instead of densely — the decompressor
    recovers positions from the label map, so only ceil(n_cp/block) blocks
    of the rank stream carry data and the accounting/serialization slices
    the stream there.
    """
    noncp = labels_flat == 0
    n_cp = (~noncp).sum()
    c_cp = jnp.cumsum(~noncp) - 1
    c_non = jnp.cumsum(noncp) - 1
    return jnp.where(noncp, n_cp + c_non, c_cp).astype(jnp.int32)


def rank_stream_bytes(n_cp: jnp.ndarray, payload_nbytes: jnp.ndarray,
                      block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Size of the sparse rank section: only the used block prefix."""
    ub = (n_cp + block - 1) // block
    return (HEADER_BYTES + (ub + 7) // 8 + ub + (block * ub + 7) // 8
            + 4 * ub + payload_nbytes).astype(jnp.int32)


# --------------------------------------------------------------------------
# Compression
# --------------------------------------------------------------------------

def _compress_measure(field: jnp.ndarray, eb: float, block: int,
                      backend: str):
    """Single-field pass 1: everything except the width-bucketed BE pack."""
    field = field.astype(jnp.float32)
    codes = quantize(field, eb)

    # --- CD + RP (the lightweight topology stage, before lossy QZ) ---
    with jax.named_scope("toposzp.stage_detect"):
        labels = ops.cp_detect(field, backend=backend)
        ranks = compute_ranks(field, labels, codes)

    # --- QZ + LZ fused over (B, K) blocks ---
    with jax.named_scope("toposzp.stage_quant"):
        first, mags, signs, widths = ops.szp_quant(
            _blocked_field(field, block), eb, backend=backend)

        # --- metadata sections ---
        labels_flat = labels.reshape(-1)
        labels2b = bitpack.pack_2bit(labels_flat)
        n_cp = (labels_flat != 0).sum().astype(jnp.int32)
        dest = _cp_first_dest(labels_flat)
        ranks_sorted = jnp.zeros(labels_flat.shape[0],
                                 jnp.int32).at[dest].set(
            ranks.reshape(-1), unique_indices=True)   # CP ranks first
        rfirst, rmags, rsigns, rwidths = _delta_blocks(
            _blocked_codes(ranks_sorted, block))
    return ((first, mags, signs, widths), (rfirst, rmags, rsigns, rwidths),
            labels2b, n_cp, widths.max(), rwidths.max())


_measure_one = jax.jit(_compress_measure,
                       static_argnames=("block", "backend"))


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _measure_batch(fields: jnp.ndarray, eb: float, block: int, backend: str):
    """Batched pass 1; both width maxes are reduced over the WHOLE batch
    in-graph so the caller's bucket decision reads one scalar pair
    instead of N per-field maxes."""
    main, rank, labels2b, n_cp, w_max, rw_max = jax.vmap(
        lambda f: _compress_measure(f, eb, block, backend))(fields)
    return main, rank, labels2b, n_cp, w_max.max(), rw_max.max()


@functools.partial(jax.jit, static_argnames=("block", "mw_main", "mw_rank",
                                             "backend", "batched"))
def _pack_streams(main, rank, labels2b, n_cp, block: int, mw_main: int,
                  mw_rank: int, backend: str,
                  batched: bool = False) -> TopoSZpCompressed:
    """Pass 2: tiled BE pack of both streams at static capacity buckets."""
    def pack(args):
        szp_parts = _assemble_parts(*args[0], mw_main, backend=backend)
        rank_parts = _assemble_parts(*args[1], mw_rank, backend=backend)
        return szp_parts, rank_parts
    with jax.named_scope("toposzp.stage_pack"):
        if batched:
            szp_parts, rank_parts = jax.vmap(pack)((main, rank))
            labels_bytes = labels2b.shape[1]
        else:
            szp_parts, rank_parts = pack((main, rank))
            labels_bytes = labels2b.shape[0]
    nbytes = (szp_parts.nbytes + labels_bytes
              + rank_stream_bytes(n_cp, rank_parts.payload_nbytes, block))
    return TopoSZpCompressed(szp_parts, labels2b, rank_parts, n_cp,
                             nbytes.astype(jnp.int32))


def _compress_resident_topo(field: jnp.ndarray, eb, block: int,
                            backend: str) -> TopoSZpCompressed:
    """Device-resident TopoSZp compress: measure + shared-bucket switch
    pack, no host syncs.  Main and rank streams are packed at the SHARED
    bucket of their joint max width (6 ``lax.switch`` branches instead of
    36 bucket pairs); valid bytes and the serialized stream are identical
    to the per-stream-bucket classic pack."""
    main, rank, labels2b, n_cp, _, _ = _compress_measure(
        field, eb, block, backend)
    with jax.named_scope("toposzp.stage_pack"):
        szp_parts, rank_parts = _pack_switch((main, rank), block, backend)
    nbytes = (szp_parts.nbytes + labels2b.shape[0]
              + rank_stream_bytes(n_cp, rank_parts.payload_nbytes, block))
    return TopoSZpCompressed(szp_parts, labels2b, rank_parts, n_cp,
                             nbytes.astype(jnp.int32))


def _compress_resident_topo_batch(fields: jnp.ndarray, eb, block: int,
                                  backend: str) -> TopoSZpCompressed:
    """Batched device-resident TopoSZp compress (bucket switch hoisted
    outside the vmap; one shared bucket for the whole batch)."""
    main, rank, labels2b, n_cp, _, _ = jax.vmap(
        lambda f: _compress_measure(f, eb, block, backend))(fields)
    with jax.named_scope("toposzp.stage_pack"):
        szp_parts, rank_parts = _pack_switch((main, rank), block, backend,
                                             batched=True)
    nbytes = (szp_parts.nbytes + labels2b.shape[1]
              + rank_stream_bytes(n_cp, rank_parts.payload_nbytes, block))
    return TopoSZpCompressed(szp_parts, labels2b, rank_parts, n_cp,
                             nbytes.astype(jnp.int32))


_topo_resident_jit = jax.jit(
    _compress_resident_topo, static_argnames=("block", "backend"))
_topo_resident_donated = jax.jit(
    _compress_resident_topo, static_argnames=("block", "backend"),
    donate_argnums=(0,))
_topo_resident_batch_jit = jax.jit(
    _compress_resident_topo_batch, static_argnames=("block", "backend"))
_topo_resident_batch_donated = jax.jit(
    _compress_resident_topo_batch, static_argnames=("block", "backend"),
    donate_argnums=(0,))


def _obs_topo_stream(comp: TopoSZpCompressed, mode: str) -> None:
    """Static stream accounting: calls + the capacity-formula bytes over
    both bitpacked streams and the label map.  Every number comes from
    array SHAPES (aval metadata, host-known without any device read), so
    recording it keeps the zero-sync guarantee on both the classic and
    the resident path."""
    if not obs.enabled():
        return
    batched = comp.szp.widths.ndim == 2
    calls = comp.szp.widths.shape[0] if batched else 1

    def cap(parts: SZpParts) -> int:
        return (HEADER_BYTES * calls + parts.const_bits.size
                + parts.widths.size + parts.signs.size
                + 4 * parts.first.size + parts.payload.size)

    total = cap(comp.szp) + cap(comp.ranks) + comp.labels2b.size
    obs.counter_add("toposzp.compress.calls", calls)
    obs.counter_add(f"toposzp.compress.{mode}_calls", calls)
    obs.counter_add("toposzp.compress.cap_bytes", float(total))


def toposzp_compress(field: jnp.ndarray, eb,
                     block: int = DEFAULT_BLOCK,
                     backend: Optional[str] = None, resident: bool = False,
                     donate: bool = False) -> TopoSZpCompressed:
    """Compress a 2-D scalar field with topology metadata.

    ``resident=True`` runs the whole compress on device (``lax.switch``
    bucket select; composes under an enclosing ``jax.jit``; worst-case
    payload capacity) with streams byte-identical to the classic two-pass
    path; ``donate=True`` (resident only) donates the field's buffer."""
    backend = ops.resolve_backend(backend)
    if resident:
        with obs.span("compress.resident", pipeline="toposzp",
                      backend=backend):
            if donate:
                with _quiet_donation():
                    comp = _topo_resident_donated(field, eb, block=block,
                                                  backend=backend)
            else:
                comp = _topo_resident_jit(field, eb, block=block,
                                          backend=backend)
        _obs_topo_stream(comp, "resident")
        return comp
    with obs.span("compress.quant", pipeline="toposzp", backend=backend,
                  includes="detect+quant"):
        main, rank, labels2b, n_cp, w_max, rw_max = _measure_one(
            field, eb, block=block, backend=backend)
        # one blocking read for both width maxes
        wm, rwm = np.asarray(jnp.stack([w_max, rw_max]))
        mw_main = bitpack.width_bucket(int(wm))
        mw_rank = bitpack.width_bucket(int(rwm))
    with obs.span("compress.pack", pipeline="toposzp",
                  width_bucket=mw_main, rank_bucket=mw_rank):
        comp = _pack_streams(main, rank, labels2b, n_cp, block=block,
                             mw_main=mw_main, mw_rank=mw_rank,
                             backend=backend)
    _obs_topo_stream(comp, "classic")
    obs.counter_add(f"toposzp.compress.bucket_{mw_main}", 1)
    return comp


def toposzp_compress_batch(fields: jnp.ndarray, eb,
                           block: int = DEFAULT_BLOCK,
                           backend: Optional[str] = None,
                           resident: bool = False,
                           donate: bool = False) -> TopoSZpCompressed:
    """Compress N stacked same-shape fields in one compiled call.

    ``fields`` is (N, ny, nx); every array of the result carries a leading
    batch axis.  Streams are byte-identical to N per-field calls (the
    shared capacity bucket covers the batch max width; valid bytes are
    unaffected).  Use :func:`batch_slice` / :func:`serialize` helpers to
    recover per-field streams.  ``resident=True``/``donate=True`` as in
    :func:`toposzp_compress`; the classic path's width→bucket decision is
    one reduce over the whole batch (a single scalar-pair read, not N
    per-field syncs).
    """
    if fields.ndim != 3:
        raise ValueError(f"expected (N, ny, nx) fields, got {fields.shape}")
    backend = ops.resolve_backend(backend)
    if resident:
        with obs.span("compress.resident", pipeline="toposzp",
                      backend=backend, batch=fields.shape[0]):
            if donate:
                with _quiet_donation():
                    comp = _topo_resident_batch_donated(
                        fields, eb, block=block, backend=backend)
            else:
                comp = _topo_resident_batch_jit(fields, eb, block=block,
                                                backend=backend)
        _obs_topo_stream(comp, "resident")
        return comp
    with obs.span("compress.quant", pipeline="toposzp", backend=backend,
                  includes="detect+quant", batch=fields.shape[0]):
        main, rank, labels2b, n_cp, w_max, rw_max = _measure_batch(
            fields, eb, block=block, backend=backend)
        wm, rwm = np.asarray(jnp.stack([w_max, rw_max]))
        mw_main = bitpack.width_bucket(int(wm))
        mw_rank = bitpack.width_bucket(int(rwm))
    with obs.span("compress.pack", pipeline="toposzp",
                  width_bucket=mw_main, rank_bucket=mw_rank):
        comp = _pack_streams(main, rank, labels2b, n_cp, block=block,
                             mw_main=mw_main, mw_rank=mw_rank,
                             backend=backend, batched=True)
    _obs_topo_stream(comp, "classic")
    obs.counter_add(f"toposzp.compress.bucket_{mw_main}", fields.shape[0])
    return comp


def batch_slice(comp: TopoSZpCompressed, i: int) -> TopoSZpCompressed:
    """Per-field view of a batched stream (arrays indexed on the batch
    axis); byte-identical to the per-field API's output."""
    return jax.tree_util.tree_map(lambda a: a[i], comp)


def pages_as_fields(pages: jnp.ndarray) -> jnp.ndarray:
    """KV-page stack (N, S_page, ...feature dims) -> (N, C, S_page) f32
    2-D field views for the batched compress APIs.

    The feature dims fold into the row (y) axis and the page's sequence dim
    becomes the x axis, so the SZp block deltas run along consecutive
    positions of one channel — the temporally smooth direction of KV data —
    and the CP/rank metadata sees each channel's position profile as a
    scanline.  Inverse: :func:`fields_as_pages`.
    """
    if pages.ndim < 3:
        raise ValueError(f"expected (N, S_page, ...) pages, got {pages.shape}")
    n, s = pages.shape[0], pages.shape[1]
    flat = pages.reshape(n, s, -1)
    return jnp.swapaxes(flat, 1, 2).astype(jnp.float32)


def fields_as_pages(fields: jnp.ndarray, page_shape: Sequence[int],
                    dtype=None) -> jnp.ndarray:
    """(N, C, S_page) field views back to (N, *page_shape) pages."""
    n = fields.shape[0]
    pages = jnp.swapaxes(fields, 1, 2).reshape((n,) + tuple(page_shape))
    return pages if dtype is None else pages.astype(dtype)


# --------------------------------------------------------------------------
# Decompression
# --------------------------------------------------------------------------

def _decode_field(comp: TopoSZpCompressed, shape, eb: float, block: int,
                  recon: str, deq_backend: str, backend: str):
    """BE^ -> LZ^+B^ -> QZ^ -> MD^ for one field -> (base, labels, ranks)."""
    ny, nx = shape
    n = ny * nx

    # --- QZ^ through the kernel dequant (guarded by the caller) ---
    mags, signs, _ = _unpack_sections(comp.szp, block)
    base = ops.szp_dequant(comp.szp.first, mags, signs[:, 1:], eb,
                           backend=deq_backend)
    if recon == "left":
        base = base - eb
    elif recon != "center":
        raise ValueError(f"unknown recon mode: {recon}")
    base = base.reshape(-1)[:n].reshape(shape)

    # --- MD^: metadata extraction ---
    labels = bitpack.unpack_2bit(comp.labels2b, n).reshape(shape)
    labels_flat = labels.reshape(-1)
    # sparse rank stream: CP-first order; the stream may be trimmed to its
    # used prefix (deserialization), so decode its actual block count.
    # Rank codes must stay lossless -> always the exact int32 path.
    n_codes = comp.ranks.widths.shape[0] * block
    ranks_sorted = decompress_codes(comp.ranks, min(n_codes, n), block=block)
    if n_codes < n:
        ranks_sorted = jnp.concatenate(
            [ranks_sorted, jnp.zeros(n - n_codes, jnp.int32)])
    dest = _cp_first_dest(labels_flat)
    ranks = ranks_sorted[:n][dest].reshape(shape)
    return base, labels, ranks


def _restore_field(base, labels, ranks, eb: float, rbf_mode: str,
                   backend: str):
    """CP^+RP^ -> RS^ -> FP/FT suppression for one decoded field."""
    with jax.named_scope("toposzp.stage_restore"):
        ext, _ = apply_extrema_stencils(base, labels, ranks, eb,
                                        backend=backend)
        ref, _ = refine_saddles(ext, labels, eb, rbf_mode=rbf_mode,
                                backend=backend)
        out, _ = enforce_no_fp_ft(base, ref, labels)
    return out


@functools.partial(jax.jit, static_argnames=("shape", "block", "rbf_mode",
                                             "recon", "backend"))
def _decompress_one(comp, eb, shape, block, rbf_mode, recon, backend):
    """Single-field decompress behind the in-graph 2^24 dequant guard (a
    ``lax.cond`` on the device-computed max width — no host sync)."""
    def run(deq_backend):
        def fn(c):
            base, labels, ranks = _decode_field(c, shape, eb, block, recon,
                                                deq_backend, backend)
            return _restore_field(base, labels, ranks, eb, rbf_mode, backend)
        return fn
    if backend == "jnp":
        return run("jnp")(comp)
    overflow = (comp.szp.widths.astype(jnp.int32).max()
                >= tri_guard_width(block))
    return jax.lax.cond(overflow, run("jnp"), run(backend), comp)


@functools.partial(jax.jit, static_argnames=("shape", "block", "rbf_mode",
                                             "recon", "backend"))
def _decompress_batch(comp, eb, shape, block, rbf_mode, recon, backend):
    """Batched decompress; the dequant guard ``lax.cond`` is hoisted
    OUTSIDE the vmap (scalar max over the whole batch's widths) — under
    vmap a cond lowers to ``select`` and executes both branches."""
    def run(deq_backend):
        def one(c):
            base, labels, ranks = _decode_field(c, shape, eb, block, recon,
                                                deq_backend, backend)
            return _restore_field(base, labels, ranks, eb, rbf_mode, backend)
        return lambda cb: jax.vmap(one)(cb)
    if backend == "jnp":
        return run("jnp")(comp)
    overflow = (comp.szp.widths.astype(jnp.int32).max()
                >= tri_guard_width(block))
    return jax.lax.cond(overflow, run("jnp"), run(backend), comp)


def toposzp_decompress(comp: TopoSZpCompressed, shape: Sequence[int],
                       eb, block: int = DEFAULT_BLOCK,
                       rbf_mode: str = "shepard", recon: str = "center",
                       backend: Optional[str] = None) -> jnp.ndarray:
    """Decompress with extrema restoration + RBF saddle refinement.

    Device-resident: the 2^24 dequant-exactness guard runs as an in-graph
    ``lax.cond``, so the call never syncs to the host and composes under
    an enclosing ``jax.jit``.

    Guarantees on the output (tested in tests/test_toposzp_guarantees.py),
    independent of the backend:
      * |out - orig| <= 2 eb (relaxed-but-strict bound, paper Table I)
      * zero FP, zero FT w.r.t. the original label map
    """
    backend = ops.resolve_backend(backend)
    with obs.span("decompress.restore", pipeline="toposzp", backend=backend):
        out = _decompress_one(comp, eb, shape=tuple(shape), block=block,
                              rbf_mode=rbf_mode, recon=recon,
                              backend=backend)
    obs.counter_add("toposzp.decompress.calls", 1)
    return out


def toposzp_decompress_batch(comp: TopoSZpCompressed, shape: Sequence[int],
                             eb, block: int = DEFAULT_BLOCK,
                             rbf_mode: str = "shepard",
                             recon: str = "center",
                             backend: Optional[str] = None) -> jnp.ndarray:
    """Decompress a batched stream -> (N, ny, nx); equal to stacking N
    per-field :func:`toposzp_decompress` calls.  Device-resident (in-graph
    dequant guard, no host syncs)."""
    backend = ops.resolve_backend(backend)
    nb = comp.szp.widths.shape[0]
    with obs.span("decompress.restore", pipeline="toposzp", backend=backend,
                  batch=nb):
        out = _decompress_batch(comp, eb, shape=tuple(shape), block=block,
                                rbf_mode=rbf_mode, recon=recon,
                                backend=backend)
    obs.counter_add("toposzp.decompress.calls", nb)
    return out


def toposzp_roundtrip(field: jnp.ndarray, eb: float,
                      block: int = DEFAULT_BLOCK,
                      rbf_mode: str = "shepard",
                      backend: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, TopoSZpCompressed]:
    comp = toposzp_compress(field, eb, block=block, backend=backend)
    out = toposzp_decompress(comp, tuple(field.shape), eb, block=block,
                             rbf_mode=rbf_mode, backend=backend)
    return out, comp
