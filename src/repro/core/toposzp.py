"""TopoSZp: the full topology-aware compression pipeline (paper Sec. IV).

Compression  :  CD + RP  ->  QZ  ->  B + LZ  ->  BE        (Sec. IV-A)
Decompression:  BE^ -> LZ^+B^ -> QZ^ -> MD^ -> CP^+RP^ -> RS^  (Sec. IV-B)

Stream layout = SZp sections (1)-(5) plus (6) the 2-bit critical-point label
map and (7) the relative-order metadata, itself re-compressed with a second
lossless B+LZ+BE pass (paper Fig. 6).

Every stage dispatches through ``kernels.ops`` (``backend={"pallas",
"interpret","jnp"}``; ``None`` resolves to the hardware default): CD via
``cp_detect``, QZ+LZ via the fused ``szp_quant``, BE via the tiled
two-pass pack (static capacity = measured width bucket, see core/szp.py),
QZ^ via ``szp_dequant`` behind the |code|<2^24 tri-matmul guard, CP^+RP^
via ``extrema_restore`` and RS^ via the separable ``shepard_refine``.
Stream bytes are bit-identical across all backends.  The rank stream
(section 7) must stay lossless, so its decode always takes the exact
int32-cumsum path regardless of backend.

``toposzp_compress_batch`` / ``toposzp_decompress_batch`` stack N
same-shape fields into ONE compiled call (vmap = grid over the batch dim),
so multi-field workloads (checkpoint shards, the fig7 bench) stop paying a
dispatch + trace per field.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.guarantees import enforce_no_fp_ft
from repro.core.quantize import quantize
from repro.core.rbf import refine_saddles
from repro.core.relative_order import compute_ranks
from repro.core.stencils import apply_extrema_stencils
from repro.core.szp import (DEFAULT_BLOCK, HEADER_BYTES, SZpParts,
                            _assemble_parts, _blocked_codes, _blocked_field,
                            _delta_blocks, _dequant_backend_for,
                            _unpack_sections, decompress_codes)
from repro.kernels import ops


class TopoSZpCompressed(NamedTuple):
    """Full TopoSZp stream: SZp sections + topology metadata sections.

    The batched APIs use the same container with a leading batch axis on
    every array (``batch_slice`` recovers the per-field view).
    """
    szp: SZpParts                # sections (1)-(5)
    labels2b: jnp.ndarray        # section (6): packed 2-bit label map
    ranks: SZpParts              # section (7): lossless B+LZ+BE over ranks
    n_cp: jnp.ndarray            # () int32 critical point count
    nbytes: jnp.ndarray          # () int32 total compressed size


def _cp_first_dest(labels_flat: jnp.ndarray) -> jnp.ndarray:
    """Destination index of every point under the stable CP-first partition.

    Equivalent to inverting ``argsort(labels == 0, stable)`` but realized
    as two prefix sums + a select — O(n) instead of a full sort on the
    decompression AND compression hot paths.

    Beyond-paper ratio optimization (§Perf/compression): ranks are stored
    only for the n_cp critical points instead of densely — the decompressor
    recovers positions from the label map, so only ceil(n_cp/block) blocks
    of the rank stream carry data and the accounting/serialization slices
    the stream there.
    """
    noncp = labels_flat == 0
    n_cp = (~noncp).sum()
    c_cp = jnp.cumsum(~noncp) - 1
    c_non = jnp.cumsum(noncp) - 1
    return jnp.where(noncp, n_cp + c_non, c_cp).astype(jnp.int32)


def rank_stream_bytes(n_cp: jnp.ndarray, payload_nbytes: jnp.ndarray,
                      block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Size of the sparse rank section: only the used block prefix."""
    ub = (n_cp + block - 1) // block
    return (HEADER_BYTES + (ub + 7) // 8 + ub + (block * ub + 7) // 8
            + 4 * ub + payload_nbytes).astype(jnp.int32)


# --------------------------------------------------------------------------
# Compression
# --------------------------------------------------------------------------

def _compress_measure(field: jnp.ndarray, eb: float, block: int,
                      backend: str):
    """Single-field pass 1: everything except the width-bucketed BE pack."""
    field = field.astype(jnp.float32)
    codes = quantize(field, eb)

    # --- CD + RP (the lightweight topology stage, before lossy QZ) ---
    labels = ops.cp_detect(field, backend=backend)
    ranks = compute_ranks(field, labels, codes)

    # --- QZ + LZ fused over (B, K) blocks ---
    first, mags, signs, widths = ops.szp_quant(
        _blocked_field(field, block), eb, backend=backend)

    # --- metadata sections ---
    labels_flat = labels.reshape(-1)
    labels2b = bitpack.pack_2bit(labels_flat)
    n_cp = (labels_flat != 0).sum().astype(jnp.int32)
    dest = _cp_first_dest(labels_flat)
    ranks_sorted = jnp.zeros(labels_flat.shape[0], jnp.int32).at[dest].set(
        ranks.reshape(-1), unique_indices=True)   # CP ranks first, zeros after
    rfirst, rmags, rsigns, rwidths = _delta_blocks(
        _blocked_codes(ranks_sorted, block))
    return ((first, mags, signs, widths), (rfirst, rmags, rsigns, rwidths),
            labels2b, n_cp, widths.max(), rwidths.max())


_measure_one = jax.jit(_compress_measure,
                       static_argnames=("block", "backend"))


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def _measure_batch(fields: jnp.ndarray, eb: float, block: int, backend: str):
    out = jax.vmap(
        lambda f: _compress_measure(f, eb, block, backend))(fields)
    return out


@functools.partial(jax.jit, static_argnames=("block", "mw_main", "mw_rank",
                                             "backend", "batched"))
def _pack_streams(main, rank, labels2b, n_cp, block: int, mw_main: int,
                  mw_rank: int, backend: str,
                  batched: bool = False) -> TopoSZpCompressed:
    """Pass 2: tiled BE pack of both streams at static capacity buckets."""
    def pack(args):
        szp_parts = _assemble_parts(*args[0], mw_main, backend=backend)
        rank_parts = _assemble_parts(*args[1], mw_rank, backend=backend)
        return szp_parts, rank_parts
    if batched:
        szp_parts, rank_parts = jax.vmap(pack)((main, rank))
        labels_bytes = labels2b.shape[1]
    else:
        szp_parts, rank_parts = pack((main, rank))
        labels_bytes = labels2b.shape[0]
    nbytes = (szp_parts.nbytes + labels_bytes
              + rank_stream_bytes(n_cp, rank_parts.payload_nbytes, block))
    return TopoSZpCompressed(szp_parts, labels2b, rank_parts, n_cp,
                             nbytes.astype(jnp.int32))


def toposzp_compress(field: jnp.ndarray, eb: float,
                     block: int = DEFAULT_BLOCK,
                     backend: Optional[str] = None) -> TopoSZpCompressed:
    """Compress a 2-D scalar field with topology metadata."""
    backend = ops.resolve_backend(backend)
    main, rank, labels2b, n_cp, w_max, rw_max = _measure_one(
        field, eb, block=block, backend=backend)
    return _pack_streams(main, rank, labels2b, n_cp, block=block,
                         mw_main=bitpack.width_bucket(int(w_max)),
                         mw_rank=bitpack.width_bucket(int(rw_max)),
                         backend=backend)


def toposzp_compress_batch(fields: jnp.ndarray, eb: float,
                           block: int = DEFAULT_BLOCK,
                           backend: Optional[str] = None
                           ) -> TopoSZpCompressed:
    """Compress N stacked same-shape fields in one compiled call.

    ``fields`` is (N, ny, nx); every array of the result carries a leading
    batch axis.  Streams are byte-identical to N per-field calls (the
    shared capacity bucket covers the batch max width; valid bytes are
    unaffected).  Use :func:`batch_slice` / :func:`serialize` helpers to
    recover per-field streams.
    """
    if fields.ndim != 3:
        raise ValueError(f"expected (N, ny, nx) fields, got {fields.shape}")
    backend = ops.resolve_backend(backend)
    main, rank, labels2b, n_cp, w_max, rw_max = _measure_batch(
        fields, eb, block=block, backend=backend)
    return _pack_streams(main, rank, labels2b, n_cp, block=block,
                         mw_main=bitpack.width_bucket(int(w_max.max())),
                         mw_rank=bitpack.width_bucket(int(rw_max.max())),
                         backend=backend, batched=True)


def batch_slice(comp: TopoSZpCompressed, i: int) -> TopoSZpCompressed:
    """Per-field view of a batched stream (arrays indexed on the batch
    axis); byte-identical to the per-field API's output."""
    return jax.tree_util.tree_map(lambda a: a[i], comp)


def pages_as_fields(pages: jnp.ndarray) -> jnp.ndarray:
    """KV-page stack (N, S_page, ...feature dims) -> (N, C, S_page) f32
    2-D field views for the batched compress APIs.

    The feature dims fold into the row (y) axis and the page's sequence dim
    becomes the x axis, so the SZp block deltas run along consecutive
    positions of one channel — the temporally smooth direction of KV data —
    and the CP/rank metadata sees each channel's position profile as a
    scanline.  Inverse: :func:`fields_as_pages`.
    """
    if pages.ndim < 3:
        raise ValueError(f"expected (N, S_page, ...) pages, got {pages.shape}")
    n, s = pages.shape[0], pages.shape[1]
    flat = pages.reshape(n, s, -1)
    return jnp.swapaxes(flat, 1, 2).astype(jnp.float32)


def fields_as_pages(fields: jnp.ndarray, page_shape: Sequence[int],
                    dtype=None) -> jnp.ndarray:
    """(N, C, S_page) field views back to (N, *page_shape) pages."""
    n = fields.shape[0]
    pages = jnp.swapaxes(fields, 1, 2).reshape((n,) + tuple(page_shape))
    return pages if dtype is None else pages.astype(dtype)


# --------------------------------------------------------------------------
# Decompression
# --------------------------------------------------------------------------

def _decode_field(comp: TopoSZpCompressed, shape, eb: float, block: int,
                  recon: str, deq_backend: str, backend: str):
    """BE^ -> LZ^+B^ -> QZ^ -> MD^ for one field -> (base, labels, ranks)."""
    ny, nx = shape
    n = ny * nx

    # --- QZ^ through the kernel dequant (guarded by the caller) ---
    mags, signs, _ = _unpack_sections(comp.szp, block)
    base = ops.szp_dequant(comp.szp.first, mags, signs[:, 1:], eb,
                           backend=deq_backend)
    if recon == "left":
        base = base - eb
    elif recon != "center":
        raise ValueError(f"unknown recon mode: {recon}")
    base = base.reshape(-1)[:n].reshape(shape)

    # --- MD^: metadata extraction ---
    labels = bitpack.unpack_2bit(comp.labels2b, n).reshape(shape)
    labels_flat = labels.reshape(-1)
    # sparse rank stream: CP-first order; the stream may be trimmed to its
    # used prefix (deserialization), so decode its actual block count.
    # Rank codes must stay lossless -> always the exact int32 path.
    n_codes = comp.ranks.widths.shape[0] * block
    ranks_sorted = decompress_codes(comp.ranks, min(n_codes, n), block=block)
    if n_codes < n:
        ranks_sorted = jnp.concatenate(
            [ranks_sorted, jnp.zeros(n - n_codes, jnp.int32)])
    dest = _cp_first_dest(labels_flat)
    ranks = ranks_sorted[:n][dest].reshape(shape)
    return base, labels, ranks


def _restore_field(base, labels, ranks, eb: float, rbf_mode: str,
                   backend: str):
    """CP^+RP^ -> RS^ -> FP/FT suppression for one decoded field."""
    ext, _ = apply_extrema_stencils(base, labels, ranks, eb, backend=backend)
    ref, _ = refine_saddles(ext, labels, eb, rbf_mode=rbf_mode,
                            backend=backend)
    out, _ = enforce_no_fp_ft(base, ref, labels)
    return out


@functools.partial(jax.jit, static_argnames=("shape", "block", "rbf_mode",
                                             "recon", "deq_backend",
                                             "backend"))
def _decompress_one(comp, eb, shape, block, rbf_mode, recon, deq_backend,
                    backend):
    base, labels, ranks = _decode_field(comp, shape, eb, block, recon,
                                        deq_backend, backend)
    return _restore_field(base, labels, ranks, eb, rbf_mode, backend)


@functools.partial(jax.jit, static_argnames=("shape", "block", "rbf_mode",
                                             "recon", "deq_backend",
                                             "backend"))
def _decompress_batch(comp, eb, shape, block, rbf_mode, recon, deq_backend,
                      backend):
    def one(c):
        base, labels, ranks = _decode_field(c, shape, eb, block, recon,
                                            deq_backend, backend)
        return _restore_field(base, labels, ranks, eb, rbf_mode, backend)
    return jax.vmap(one)(comp)


def toposzp_decompress(comp: TopoSZpCompressed, shape: Sequence[int],
                       eb: float, block: int = DEFAULT_BLOCK,
                       rbf_mode: str = "shepard", recon: str = "center",
                       backend: Optional[str] = None) -> jnp.ndarray:
    """Decompress with extrema restoration + RBF saddle refinement.

    Guarantees on the output (tested in tests/test_toposzp_guarantees.py),
    independent of the backend:
      * |out - orig| <= 2 eb (relaxed-but-strict bound, paper Table I)
      * zero FP, zero FT w.r.t. the original label map
    """
    backend = ops.resolve_backend(backend)
    deq_backend = _dequant_backend_for(comp.szp, block, backend)
    return _decompress_one(comp, eb, shape=tuple(shape), block=block,
                           rbf_mode=rbf_mode, recon=recon,
                           deq_backend=deq_backend, backend=backend)


def toposzp_decompress_batch(comp: TopoSZpCompressed, shape: Sequence[int],
                             eb: float, block: int = DEFAULT_BLOCK,
                             rbf_mode: str = "shepard",
                             recon: str = "center",
                             backend: Optional[str] = None) -> jnp.ndarray:
    """Decompress a batched stream -> (N, ny, nx); equal to stacking N
    per-field :func:`toposzp_decompress` calls."""
    backend = ops.resolve_backend(backend)
    deq_backend = _dequant_backend_for(comp.szp, block, backend)
    return _decompress_batch(comp, eb, shape=tuple(shape), block=block,
                             rbf_mode=rbf_mode, recon=recon,
                             deq_backend=deq_backend, backend=backend)


def toposzp_roundtrip(field: jnp.ndarray, eb: float,
                      block: int = DEFAULT_BLOCK,
                      rbf_mode: str = "shepard",
                      backend: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, TopoSZpCompressed]:
    comp = toposzp_compress(field, eb, block=block, backend=backend)
    out = toposzp_decompress(comp, tuple(field.shape), eb, block=block,
                             rbf_mode=rbf_mode, backend=backend)
    return out, comp
