"""Pallas TPU kernel: tiled bit-packing (SZp "BE" stage, phase 1).

Every block of K magnitudes is packed at its LOCAL offset 0 into
``ceil(K*max_width/8)`` bytes — the global compaction (a collision-free
scatter to the per-block byte offsets) stays in XLA, see
``core.bitpack.compact_local_bytes``.  This removes the two costs of the
legacy one-shot packer: the per-output-byte ``searchsorted`` byte->block
map, and the 32-bit worst-case capacity (the static ``max_width`` comes
from the measured widths lifted to a ``core.bitpack.WIDTH_BUCKETS`` entry).

Kernel form (branch-free VPU ops on a (TB, NBM) tile): for each of the K
values, its w-bit window lands at stream bits [i*w, i*w+w); the
contribution to output byte j is ``v << s`` / ``v >> -s`` with
``s = i*w - 8*j``, masked to the overlap — a K-step unrolled shift-and-or.

Validated against ``core.bitpack.local_pack_bytes`` in interpret mode
(tests/test_bitpack.py, tests/test_backend_parity.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TB = 256  # blocks per grid instance


def _make_pack_kernel(k: int, nbm: int):
    def kernel(mags_ref, widths_ref, out_ref):
        mags = mags_ref[...].astype(jnp.uint32)           # (TB, K)
        w = widths_ref[...]                               # (TB, 1) i32
        tb = mags.shape[0]
        j8 = 8 * jax.lax.broadcasted_iota(jnp.int32, (tb, nbm), 1)
        acc = jnp.zeros((tb, nbm), jnp.uint32)
        for i in range(k):
            v = mags[:, i:i + 1]                          # (TB, 1)
            s = i * w - j8                                # (TB, NBM)
            sl = jnp.clip(s, 0, 31).astype(jnp.uint32)
            sr = jnp.clip(-s, 0, 31).astype(jnp.uint32)
            contrib = jnp.where(s >= 0, v << sl, v >> sr) & jnp.uint32(0xFF)
            valid = (s < 8) & (s > -w) & (w > 0)
            acc = acc | jnp.where(valid, contrib, jnp.uint32(0))
        out_ref[...] = acc.astype(jnp.uint8)
    return kernel


@functools.partial(jax.jit, static_argnames=("max_width", "tb", "interpret"))
def local_pack_blocks(mags: jnp.ndarray, widths: jnp.ndarray,
                      max_width: int = 32, tb: int = DEFAULT_TB,
                      interpret: bool = True) -> jnp.ndarray:
    """Per-block local pack -> (B, ceil(K*max_width/8)) uint8.

    Block b's first ``ceil(K*widths[b]/8)`` bytes equal its slice of the
    ``core.bitpack.pack_blocks`` stream; the tail is zero.  B must be a
    multiple of ``tb`` (the ops.py wrapper pads).
    """
    b, k = mags.shape
    assert b % tb == 0, f"B={b} not a multiple of tile {tb}"
    nbm = (k * max_width + 7) // 8
    out = pl.pallas_call(
        _make_pack_kernel(k, nbm),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, nbm), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nbm), jnp.uint8),
        interpret=interpret,
    )(mags.astype(jnp.uint32), widths.astype(jnp.int32)[:, None])
    return out
