"""Pallas TPU kernel: fused SZp quantize + intra-block delta (QZ + LZ).

The compression hot loop: for every 1-D block of K values, emit the
quantized first element (outlier), delta signs, delta magnitudes and the
per-block bit width — everything the BE packer needs — in a single pass over
the data.

TPU mapping (DESIGN.md "hardware adaptation"): the (num_blocks, K) layout
puts the SZp block dimension in lanes; a grid instance processes a
(TB, K) tile held in VMEM.  All math is branch-free VPU ops; the bit-width
reduction is a 32-step unrolled compare-accumulate.  The inverse kernel
reconstructs codes with a cumulative sum expressed as a lower-triangular
matmul (MXU-friendly form of a lane scan).

Validated against kernels/ref.py in interpret mode (tests/test_kernels.py);
on real TPUs the same code path runs compiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_TB = 256  # blocks per grid instance


def _quant_kernel(x_ref, eb_ref, first_ref, mags_ref, signs_ref, widths_ref):
    x = x_ref[...]                                    # (TB, K) f32
    eb = eb_ref[0]
    q = jnp.floor((x + eb) / (2.0 * eb)).astype(jnp.int32)
    first_ref[...] = q[:, :1]
    deltas = q[:, 1:] - q[:, :-1]                     # (TB, K-1)
    neg = deltas < 0
    mags = jnp.where(neg, -deltas, deltas).astype(jnp.uint32)
    mags_ref[...] = mags
    signs_ref[...] = neg.astype(jnp.int32)
    # per-block bit width: unrolled compare ladder (branch-free)
    mmax = jnp.max(mags, axis=1, keepdims=True)       # (TB, 1)
    w = jnp.zeros_like(mmax, dtype=jnp.int32)
    for k in range(32):
        w += (mmax >= jnp.uint32(1 << k)).astype(jnp.int32)
    widths_ref[...] = w


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def szp_quant_blocks(xb: jnp.ndarray, eb: float, tb: int = DEFAULT_TB,
                     interpret: bool = True):
    """Fused QZ+LZ over (B, K) blocked values.

    Returns (first (B,) i32, mags (B, K-1) u32, signs (B, K-1) i32,
    widths (B,) i32).  B must be a multiple of ``tb`` (wrapper pads).
    """
    b, k = xb.shape
    assert b % tb == 0, f"B={b} not a multiple of tile {tb}"
    grid = (b // tb,)
    ebv = jnp.full((1,), eb, jnp.float32)
    first, mags, signs, widths = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, k - 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, k - 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, k - 1), jnp.uint32),
            jax.ShapeDtypeStruct((b, k - 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xb.astype(jnp.float32), ebv)
    return first[:, 0], mags, signs, widths[:, 0]


def _dequant_kernel(first_ref, mags_ref, signs_ref, eb_ref, tri_ref, out_ref):
    first = first_ref[...]                            # (TB, 1) i32
    mags = mags_ref[...].astype(jnp.int32)            # (TB, K-1)
    neg = signs_ref[...] > 0
    deltas = jnp.where(neg, -mags, mags)
    # cumulative sum along lanes as a lower-triangular matmul (MXU form);
    # exact for |codes| < 2^24 which the f32 path guarantees here, and the
    # int32 fallback in ops.py covers the full range.
    tri = tri_ref[...]                                # (K-1, K-1) f32 lower-tri
    cs = jax.lax.dot_general(deltas.astype(jnp.float32), tri,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    codes = first + jnp.concatenate(
        [jnp.zeros_like(first), cs.astype(jnp.int32)], axis=1)
    eb = eb_ref[0]
    out_ref[...] = codes.astype(jnp.float32) * (2.0 * eb)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def szp_dequant_blocks(first: jnp.ndarray, mags: jnp.ndarray,
                       signs: jnp.ndarray, eb: float, tb: int = DEFAULT_TB,
                       interpret: bool = True) -> jnp.ndarray:
    """Inverse of :func:`szp_quant_blocks` -> (B, K) f32 reconstruction."""
    b, km1 = mags.shape
    k = km1 + 1
    assert b % tb == 0
    tri = jnp.asarray(np.tril(np.ones((km1, km1), np.float32)).T)
    ebv = jnp.full((1,), eb, jnp.float32)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, km1), lambda i: (i, 0)),
            pl.BlockSpec((tb, km1), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(first[:, None], mags, signs, ebv, tri)
    return out
