"""Pallas TPU kernels for TopoSZp's compute hot spots.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec tiling) with a
pure-jnp oracle in ref.py and a jit'd public wrapper in ops.py.  On this
CPU container kernels are validated with interpret=True; on TPU the same
bodies compile through Mosaic.
"""
