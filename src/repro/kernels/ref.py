"""Pure-jnp oracles for every Pallas kernel (the allclose references).

Each function mirrors the corresponding kernel's contract exactly; the
kernel tests sweep shapes/dtypes and assert allclose (or exact equality for
integer outputs) against these.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.critical_points import classify as _classify
from repro.core.quantize import dequantize, quantize
from repro.utils import bitwidth, ulp_step


def szp_quant_blocks_ref(xb: jnp.ndarray, eb: float):
    """Oracle for kernels.szp_quant.szp_quant_blocks."""
    q = quantize(xb, eb)
    first = q[:, 0]
    deltas = q[:, 1:] - q[:, :-1]
    signs = (deltas < 0).astype(jnp.int32)
    mags = jnp.abs(deltas).astype(jnp.uint32)
    widths = bitwidth(mags.max(axis=1))
    return first, mags, signs, widths


def szp_dequant_blocks_ref(first, mags, signs, eb: float):
    """Oracle for kernels.szp_quant.szp_dequant_blocks."""
    deltas = jnp.where(signs > 0, -(mags.astype(jnp.int32)),
                       mags.astype(jnp.int32))
    codes = first[:, None] + jnp.concatenate(
        [jnp.zeros((first.shape[0], 1), jnp.int32),
         jnp.cumsum(deltas, axis=1)], axis=1)
    return dequantize(codes, eb)


def local_pack_ref(mags: jnp.ndarray, widths: jnp.ndarray,
                   max_width: int = 32) -> jnp.ndarray:
    """Oracle for kernels.bitpack_pack.local_pack_blocks."""
    from repro.core.bitpack import local_pack_bytes
    return local_pack_bytes(mags, widths, max_width)


def compact_bytes_ref(local: jnp.ndarray, widths: jnp.ndarray, k: int):
    """Oracle for kernels.bitpack_compact.compact_local_blocks (same
    (buf, offs, total) contract as the XLA scatter)."""
    from repro.core.bitpack import compact_local_bytes
    return compact_local_bytes(local, widths, k)


def cp_detect_ref(field: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.cp_detect.cp_detect (== core classify)."""
    return _classify(field)


def extrema_restore_ref(recon, labels, cur_labels, ranks, eb: float):
    """Oracle for kernels.extrema_restore.extrema_restore."""
    from repro.core.critical_points import neighbor_min_max
    recon = recon.astype(jnp.float32)
    nmin, nmax = neighbor_min_max(recon)
    delta = jnp.maximum(ranks, 1)
    tgt_min = ulp_step(nmin, -delta)
    tgt_max = ulp_step(nmax, +delta)
    lost_min = (labels == 1) & (cur_labels != 1)
    lost_max = (labels == 3) & (cur_labels != 3)
    ok_min = lost_min & (tgt_min >= recon - eb) & (tgt_min <= recon + eb)
    ok_max = lost_max & (tgt_max >= recon - eb) & (tgt_max <= recon + eb)
    out = jnp.where(ok_min, tgt_min, recon)
    return jnp.where(ok_max, tgt_max, out)


def shepard_refine_global_ref(field: jnp.ndarray, sigma=0.75,
                              radius=2) -> jnp.ndarray:
    """Oracle for kernels.rbf_refine.shepard_refine_global.

    Full (non-separable) 7x7 window with global sigma/Chebyshev radius
    (traced scalars, like the kernel), center excluded, edge-replicated —
    the direct form of eq. (2).
    """
    from repro.core.rbf import MAX_RADIUS, _offsets, _window_patches
    f = field.astype(jnp.float32)
    sigma = jnp.asarray(sigma, jnp.float32)
    patches = _window_patches(f, MAX_RADIUS)
    dy, dx = _offsets(MAX_RADIUS)
    dist2 = (dy ** 2 + dx ** 2).astype(jnp.float32)
    w = jnp.exp(-dist2 / (2.0 * sigma * sigma))
    keep = ((jnp.maximum(jnp.abs(dy), jnp.abs(dx))
             <= jnp.asarray(radius, jnp.int32)) & (dist2 > 0))
    w = jnp.where(keep, w, 0.0)
    return (patches * w[None, None, :]).sum(-1) / w.sum()
