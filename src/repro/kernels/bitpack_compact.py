"""Pallas TPU kernel: byte-stream compaction (SZp "BE" stage, phase 2).

Phase 1 (``kernels/bitpack_pack.py``) leaves every block's packed bytes at
LOCAL offset 0 of a (B, NBM) tile; this kernel moves block b's row to its
global byte offset, producing the contiguous payload.  It replaces the XLA
scatter of ``core.bitpack.compact_local_bytes`` (a (B*NBM,)-index
``.at[].set`` with drop-mode bounds handling) with dynamic row stores: the
grid walks block tiles in order and each block writes its NBM-byte row at
``out[offs[b] : offs[b]+NBM]``.

Correctness of the overlapping stores relies on the TPU grid being
sequential and ``fori_loop`` ordering rows within a tile: block b's window
may reach into block b+1's bytes (its zero tail), but b+1 stores later and
rewrites them, so the last writer of every valid byte is its owning block.
Zero-width blocks (and tile-padding rows) are skipped entirely, which also
keeps every issued store inside the ``B*NBM`` capacity.

The full output lives in one revisited VMEM block, so ``cap = B*NBM`` must
fit VMEM — true for every capacity the two-pass pack produces on
block-32 fields up to the multi-megabyte range.  Validated against
``core.bitpack.compact_local_bytes`` in interpret mode
(tests/test_device_resident.py, tests/test_backend_parity.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TB = 256  # blocks per grid instance


def _make_compact_kernel(nbm: int, tb: int):
    def kernel(local_ref, offs_ref, nb_ref, out_ref):
        @pl.when(pl.program_id(0) == 0)
        def _zero_init():
            out_ref[...] = jnp.zeros_like(out_ref)

        def body(r, carry):
            off = offs_ref[r, 0]
            nb = nb_ref[r, 0]

            @pl.when(nb > 0)
            def _store_row():
                out_ref[0, pl.ds(off, nbm)] = local_ref[r, :]
            return carry

        jax.lax.fori_loop(0, tb, body, 0)
    return kernel


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def compact_local_blocks(local: jnp.ndarray, offs: jnp.ndarray,
                         nb: jnp.ndarray, tb: int = DEFAULT_TB,
                         interpret: bool = True) -> jnp.ndarray:
    """Scatter (B, NBM) local rows to their global offsets -> (cap,) uint8.

    ``offs``/``nb`` are (B,) int32 exclusive byte offsets / valid byte
    counts (``core.bitpack.block_nbytes`` of the widths); rows with
    ``nb == 0`` are skipped.  B must be a multiple of ``tb`` (the ops.py
    wrapper pads with ``nb == 0`` rows).  Bytes past the valid total are 0,
    matching the ``compact_local_bytes`` contract.
    """
    b, nbm = local.shape
    assert b % tb == 0, f"B={b} not a multiple of tile {tb}"
    cap = b * nbm
    out = pl.pallas_call(
        _make_compact_kernel(nbm, tb),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, nbm), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, cap), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, cap), jnp.uint8),
        interpret=interpret,
    )(local, offs.astype(jnp.int32)[:, None], nb.astype(jnp.int32)[:, None])
    return out[0]
