"""Pallas TPU kernel: fused extrema-stencil restoration (paper CP^+RP^).

For every lost extremum, move the reconstruction delta ULPs past the
min/max of its 4-neighborhood, skipping corrections that leave the +-eb
budget.  ULP stepping is done in the monotone IEEE-754 integer ordering —
pure int32 bit ops on the VPU (see utils.ulp_step for the host version).

Same shifted-operand halo pattern as cp_detect.py; fully elementwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.cp_detect import _shifts

DEFAULT_TY, DEFAULT_TX = 128, 128
_INT32_MIN = -(2 ** 31)


def _f2i(x):
    i = jax.lax.bitcast_convert_type(x, jnp.int32)
    return jnp.where(i < 0, jnp.int32(_INT32_MIN) - i, i)


def _i2f(i):
    raw = jnp.where(i < 0, jnp.int32(_INT32_MIN) - i, i)
    return jax.lax.bitcast_convert_type(raw, jnp.float32)


def _restore_kernel(ny_nx_eb_ref, f_ref, t_ref, d_ref, l_ref, r_ref,
                    lab_ref, cur_ref, rank_ref, out_ref):
    f = f_ref[...]
    t, d, l, r = t_ref[...], d_ref[...], l_ref[...], r_ref[...]
    lab = lab_ref[...]
    cur = cur_ref[...]
    rank = rank_ref[...]
    ny = ny_nx_eb_ref[0].astype(jnp.int32)
    nx = ny_nx_eb_ref[1].astype(jnp.int32)
    eb = ny_nx_eb_ref[2]

    ti, tj = pl.program_id(0), pl.program_id(1)
    by, bx = f.shape
    ii = ti * by + jax.lax.broadcasted_iota(jnp.int32, (by, bx), 0)
    jj = tj * bx + jax.lax.broadcasted_iota(jnp.int32, (by, bx), 1)
    has_t, has_d = ii > 0, ii < ny - 1
    has_l, has_r = jj > 0, jj < nx - 1

    big = jnp.float32(3.4e38)
    nmin = jnp.minimum(jnp.minimum(jnp.where(has_t, t, big),
                                   jnp.where(has_d, d, big)),
                       jnp.minimum(jnp.where(has_l, l, big),
                                   jnp.where(has_r, r, big)))
    nmax = jnp.maximum(jnp.maximum(jnp.where(has_t, t, -big),
                                   jnp.where(has_d, d, -big)),
                       jnp.maximum(jnp.where(has_l, l, -big),
                                   jnp.where(has_r, r, -big)))

    delta = jnp.maximum(rank, 1)
    tgt_min = _i2f(_f2i(nmin) - delta)
    tgt_max = _i2f(_f2i(nmax) + delta)

    lost_min = (lab == 1) & (cur != 1)
    lost_max = (lab == 3) & (cur != 3)
    ok_min = lost_min & (tgt_min >= f - eb) & (tgt_min <= f + eb)
    ok_max = lost_max & (tgt_max >= f - eb) & (tgt_max <= f + eb)

    out = jnp.where(ok_min, tgt_min, f)
    out = jnp.where(ok_max, tgt_max, out)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("ty", "tx", "interpret"))
def extrema_restore(recon: jnp.ndarray, labels: jnp.ndarray,
                    cur_labels: jnp.ndarray, ranks: jnp.ndarray, eb: float,
                    ty: int = DEFAULT_TY, tx: int = DEFAULT_TX,
                    interpret: bool = True) -> jnp.ndarray:
    """Fused lost-extrema restoration; returns the corrected field."""
    ny, nx = recon.shape
    py, px = (-ny) % ty, (-nx) % tx

    def padded(a, mode="edge"):
        return jnp.pad(a, ((0, py), (0, px)), mode=mode)

    f = padded(recon.astype(jnp.float32))
    t, d, l, r = [padded(s) for s in _shifts(recon.astype(jnp.float32))]
    lab = padded(labels, mode="constant")
    cur = padded(cur_labels, mode="constant")
    rank = padded(ranks, mode="constant")
    gy, gx = f.shape[0] // ty, f.shape[1] // tx
    meta = jnp.array([ny, nx, eb], jnp.float32)
    spec = pl.BlockSpec((ty, tx), lambda i, j: (i, j))
    out = pl.pallas_call(
        _restore_kernel,
        grid=(gy, gx),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + [spec] * 8,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(f.shape, jnp.float32),
        interpret=interpret,
    )(meta, f, t, d, l, r, lab, cur, rank)
    return out[:ny, :nx]
