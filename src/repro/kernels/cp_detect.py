"""Pallas TPU kernel: critical point detection (paper "CD" stage).

Branch-free 4-neighbor stencil classification.  Halo handling follows the
shifted-operand pattern (DESIGN.md): XLA materializes the four
edge-replicated shifted views (cheap streaming copies the fusion pass folds
into the kernel's input DMA), the kernel is then purely elementwise over 5
operands and computes edge-validity masks from the grid offsets + iota.

Output labels: REGULAR=0, MINIMA=1, SADDLE=2, MAXIMA=3 (2-bit codes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TY, DEFAULT_TX = 128, 128


def _cp_kernel(ny_nx_ref, f_ref, t_ref, d_ref, l_ref, r_ref, out_ref):
    f = f_ref[...]
    t, d, l, r = t_ref[...], d_ref[...], l_ref[...], r_ref[...]
    ny = ny_nx_ref[0]
    nx = ny_nx_ref[1]

    ti, tj = pl.program_id(0), pl.program_id(1)
    by, bx = f.shape
    ii = ti * by + jax.lax.broadcasted_iota(jnp.int32, (by, bx), 0)
    jj = tj * bx + jax.lax.broadcasted_iota(jnp.int32, (by, bx), 1)
    has_t = ii > 0
    has_d = ii < ny - 1
    has_l = jj > 0
    has_r = jj < nx - 1

    hi_t = jnp.where(has_t, t > f, True)
    hi_d = jnp.where(has_d, d > f, True)
    hi_l = jnp.where(has_l, l > f, True)
    hi_r = jnp.where(has_r, r > f, True)
    lo_t = jnp.where(has_t, t < f, True)
    lo_d = jnp.where(has_d, d < f, True)
    lo_l = jnp.where(has_l, l < f, True)
    lo_r = jnp.where(has_r, r < f, True)

    is_min = hi_t & hi_d & hi_l & hi_r
    is_max = lo_t & lo_d & lo_l & lo_r
    interior = has_t & has_d & has_l & has_r
    is_saddle = interior & (((t > f) & (d > f) & (l < f) & (r < f)) |
                            ((t < f) & (d < f) & (l > f) & (r > f)))

    lab = jnp.where(is_min, 1, 0)
    lab = jnp.where(is_saddle, 2, lab)
    lab = jnp.where(is_max, 3, lab)
    out_ref[...] = lab.astype(jnp.int32)


def _shifts(field: jnp.ndarray):
    """Edge-replicated t/d/l/r shifted views (host-side XLA slices)."""
    p = jnp.pad(field, 1, mode="edge")
    ny, nx = field.shape
    return (p[:-2, 1:-1], p[2:, 1:-1], p[1:-1, :-2], p[1:-1, 2:])


@functools.partial(jax.jit, static_argnames=("ty", "tx", "interpret"))
def cp_detect(field: jnp.ndarray, ty: int = DEFAULT_TY, tx: int = DEFAULT_TX,
              interpret: bool = True) -> jnp.ndarray:
    """Classify every point of a 2-D field -> int32 labels (same shape)."""
    ny, nx = field.shape
    py, px = (-ny) % ty, (-nx) % tx
    f = jnp.pad(field.astype(jnp.float32), ((0, py), (0, px)), mode="edge")
    t, d, l, r = [jnp.pad(s, ((0, py), (0, px)), mode="edge")
                  for s in _shifts(field.astype(jnp.float32))]
    gy, gx = f.shape[0] // ty, f.shape[1] // tx
    dims = jnp.array([ny, nx], jnp.int32)
    spec = pl.BlockSpec((ty, tx), lambda i, j: (i, j))
    out = pl.pallas_call(
        _cp_kernel,
        grid=(gy, gx),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + [spec] * 5,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(f.shape, jnp.int32),
        interpret=interpret,
    )(dims, f, t, d, l, r)
    return out[:ny, :nx]
