"""Pallas TPU kernel: separable Gaussian-RBF (Shepard) refinement.

The convex neighborhood estimate of core/rbf.py with a *global* sigma/radius
is separable:  exp(-(dy^2+dx^2)/2s^2) = g(dy) g(dx),  so

  S(p)  = sum_{|dy|<=r} g(dy) R(p + dy e_y) - f(p),   R = row pass,
  W     = (sum g)^2 - 1,
  est   = S / W.

Two elementwise 7-tap passes (row then column), each a single Pallas kernel
over shifted operands — no halo DMA needed.  ``sigma``/``radius`` are
*traced* scalars: the 7 taps are computed as a tiny jnp vector and fed to
the kernel as an operand (scalar loads), so one compiled call serves every
parameter value and the batched decompressor can vmap per-field params.
This is the TPU hot path; the per-point-adaptive variant stays on the
pure-jnp path (core/rbf.py), see DESIGN.md "hardware adaptation".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TY, DEFAULT_TX = 128, 128
MAX_RADIUS = 3


def _taps(sigma, radius) -> jnp.ndarray:
    """(7,) f32 Gaussian taps for offsets -3..3, zeroed past ``radius``."""
    o = jnp.arange(-MAX_RADIUS, MAX_RADIUS + 1, dtype=jnp.float32)
    sigma = jnp.asarray(sigma, jnp.float32)
    g = jnp.exp(-(o * o) / (2.0 * sigma * sigma))
    return jnp.where(jnp.abs(o) <= jnp.asarray(radius, jnp.float32), g, 0.0)


def _pass_kernel(taps_ref, *refs):
    out_ref = refs[-1]
    acc = None
    for k, ref in enumerate(refs[:-1]):
        term = ref[...] * taps_ref[k]
        acc = term if acc is None else acc + term
    out_ref[...] = acc


def _axis_shifts(field: jnp.ndarray, axis: int):
    """Edge-replicated shifts of ``field`` by -3..+3 along ``axis``."""
    pad = [(0, 0), (0, 0)]
    pad[axis] = (MAX_RADIUS, MAX_RADIUS)
    p = jnp.pad(field, pad, mode="edge")
    n = field.shape[axis]
    outs = []
    for o in range(2 * MAX_RADIUS + 1):
        sl = [slice(None), slice(None)]
        sl[axis] = slice(o, o + n)
        outs.append(p[tuple(sl)])
    return outs


def _run_pass(field: jnp.ndarray, taps: jnp.ndarray, axis: int, ty: int,
              tx: int, interpret: bool) -> jnp.ndarray:
    ny, nx = field.shape
    py, px = (-ny) % ty, (-nx) % tx
    shifts = [jnp.pad(s, ((0, py), (0, px)), mode="edge")
              for s in _axis_shifts(field, axis)]
    gy, gx = shifts[0].shape[0] // ty, shifts[0].shape[1] // tx
    spec = pl.BlockSpec((ty, tx), lambda i, j: (i, j))
    out = pl.pallas_call(
        _pass_kernel,
        grid=(gy, gx),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] + [spec] * len(shifts),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shifts[0].shape, jnp.float32),
        interpret=interpret,
    )(taps, *shifts)
    return out[:ny, :nx]


@functools.partial(jax.jit, static_argnames=("ty", "tx", "interpret"))
def shepard_refine_global(field: jnp.ndarray, sigma=0.75, radius=2,
                          ty: int = DEFAULT_TY, tx: int = DEFAULT_TX,
                          interpret: bool = True) -> jnp.ndarray:
    """Separable convex RBF estimate of every point (center excluded)."""
    f = field.astype(jnp.float32)
    g = _taps(sigma, radius)
    row = _run_pass(f, g, axis=1, ty=ty, tx=tx, interpret=interpret)
    col = _run_pass(row, g, axis=0, ty=ty, tx=tx, interpret=interpret)
    wsum = g.sum()
    denom = jnp.maximum(wsum * wsum - 1.0, 1e-30)  # minus the center (g0=1)
    return (col - f) / denom
