"""Public jit'd wrappers for the Pallas kernels.

Every op takes ``backend=`` with three settings:
  * "pallas"     — pl.pallas_call compiled for TPU (the production path);
                   off-TPU it transparently downgrades to "interpret" so
                   the same call sites work in the CPU container
  * "interpret"  — same kernel body, interpreted on CPU (validation path)
  * "jnp"        — the pure-jnp oracle from kernels/ref.py

``resolve_backend(None)`` picks the production default for the current
hardware ("pallas" on TPU, "jnp" elsewhere — interpret mode is a
validation tool, far too slow to be a CPU production path) and honors the
``REPRO_KERNEL_BACKEND`` env override (the CI oracle leg forces "jnp").

Wrappers own all padding/unpadding so callers see natural shapes.  Row
padding follows ONE rule (``_row_tile``): the tile is capped at the padded
row count rounded up to the f32 sublane (8), and rows are padded to a
multiple of the tile — correct for any (b, tb) combination including
b < tb with non-divisible shapes (the old ``min(tb, b)`` adjustment
handed odd, non-sublane-aligned tiles like 100 or 129 to the kernel).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import bitpack_compact as _bck
from repro.kernels import bitpack_pack as _bpk
from repro.kernels import cp_detect as _cpk
from repro.kernels import extrema_restore as _exk
from repro.kernels import rbf_refine as _rbk
from repro.kernels import szp_quant as _sqk
from repro.kernels import ref as _ref
from repro.utils import cdiv, pad_to_multiple

DEFAULT_BACKEND = "interpret"
BACKENDS = ("pallas", "interpret", "jnp")
_ENV_BACKEND = "REPRO_KERNEL_BACKEND"


def resolve_backend(backend=None) -> str:
    """Resolve a backend knob ('auto'/None -> hardware default) and
    downgrade "pallas" to "interpret" when no TPU is attached."""
    if backend in (None, "auto"):
        backend = os.environ.get(_ENV_BACKEND) or (
            "pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "pallas" and jax.default_backend() != "tpu":
        return "interpret"
    return backend


def _interp(backend: str) -> bool:
    """interpret= flag for a *resolved* backend ("pallas" implies TPU)."""
    return backend != "pallas"


def _row_tile(b: int, tb: int) -> int:
    """The shared pad-to-tile rule: tile rows = min(tb, ceil(b/8)*8)."""
    return min(tb, max(8, cdiv(b, 8) * 8))


def szp_quant(xb: jnp.ndarray, eb: float, backend: str = DEFAULT_BACKEND,
              tb: int = _sqk.DEFAULT_TB):
    """Fused QZ+LZ over (B, K) blocks -> (first, mags, signs, widths)."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.szp_quant_blocks_ref(xb, eb)
    b = xb.shape[0]
    tb = _row_tile(b, tb)
    xp = pad_to_multiple(xb, tb, axis=0)
    first, mags, signs, widths = _sqk.szp_quant_blocks(
        xp, eb, tb=tb, interpret=_interp(backend))
    return first[:b], mags[:b], signs[:b], widths[:b]


def szp_dequant(first, mags, signs, eb: float,
                backend: str = DEFAULT_BACKEND, tb: int = _sqk.DEFAULT_TB):
    """Inverse of szp_quant -> (B, K) float32 reconstruction.

    The kernel's MXU tri-matmul cumulative sum is exact only while every
    partial delta sum stays below 2^24 (f32 integer exactness); callers
    must guard on the measured widths and fall back to backend="jnp"
    (int32 cumsum) past that — see core.szp._dequant_backend_for.
    """
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.szp_dequant_blocks_ref(first, mags, signs, eb)
    b = first.shape[0]
    tb = _row_tile(b, tb)
    fp = pad_to_multiple(first, tb, axis=0)
    mp = pad_to_multiple(mags, tb, axis=0)
    sp = pad_to_multiple(signs, tb, axis=0)
    out = _sqk.szp_dequant_blocks(fp, mp, sp, eb, tb=tb,
                                  interpret=_interp(backend))
    return out[:b]


def local_pack(mags: jnp.ndarray, widths: jnp.ndarray, max_width: int = 32,
               backend: str = DEFAULT_BACKEND, tb: int = _bpk.DEFAULT_TB):
    """Tiled BE phase 1: per-block local byte pack -> (B, ceil(K*mw/8))."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.local_pack_ref(mags, widths, max_width)
    b = mags.shape[0]
    tb = _row_tile(b, tb)
    mp = pad_to_multiple(mags, tb, axis=0)
    wp = pad_to_multiple(widths.astype(jnp.int32), tb, axis=0,
                         mode="constant")
    out = _bpk.local_pack_blocks(mp, wp, max_width=max_width, tb=tb,
                                 interpret=_interp(backend))
    return out[:b]


def compact_bytes(local: jnp.ndarray, widths: jnp.ndarray, k: int,
                  backend: str = DEFAULT_BACKEND, tb: int = _bck.DEFAULT_TB):
    """Tiled BE phase 2: per-block rows -> contiguous payload.

    Same ``(buf, offs, total)`` contract as
    ``core.bitpack.compact_local_bytes`` with ``cap = B * local.shape[1]``;
    the offsets prefix sum stays in XLA, only the offset-addressed byte
    moves run in the kernel.
    """
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.compact_bytes_ref(local, widths, k)
    from repro.core.bitpack import block_nbytes
    from repro.utils import exclusive_cumsum
    b = local.shape[0]
    nb = block_nbytes(widths.astype(jnp.int32), k)
    offs = exclusive_cumsum(nb)
    total = (offs[-1] + nb[-1] if b > 0 else jnp.int32(0)).astype(jnp.int32)
    tb = _row_tile(b, tb)
    lp = pad_to_multiple(local, tb, axis=0, mode="constant")
    nbp = pad_to_multiple(nb, tb, axis=0, mode="constant")
    offp = pad_to_multiple(offs, tb, axis=0, mode="constant")
    buf = _bck.compact_local_blocks(lp, offp, nbp, tb=tb,
                                    interpret=_interp(backend))
    return buf[: b * local.shape[1]], offs, total


def cp_detect(field: jnp.ndarray, backend: str = DEFAULT_BACKEND,
              ty: int = _cpk.DEFAULT_TY, tx: int = _cpk.DEFAULT_TX):
    """Critical point classification -> int32 labels."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.cp_detect_ref(field)
    return _cpk.cp_detect(field, ty=ty, tx=tx, interpret=_interp(backend))


def extrema_restore(recon, labels, cur_labels, ranks, eb: float,
                    backend: str = DEFAULT_BACKEND,
                    ty: int = _exk.DEFAULT_TY, tx: int = _exk.DEFAULT_TX):
    """Fused lost-extrema restoration -> corrected field."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.extrema_restore_ref(recon, labels, cur_labels, ranks, eb)
    return _exk.extrema_restore(recon, labels, cur_labels, ranks, eb,
                                ty=ty, tx=tx, interpret=_interp(backend))


def shepard_refine(field: jnp.ndarray, sigma: float = 0.75, radius: int = 2,
                   backend: str = DEFAULT_BACKEND,
                   ty: int = _rbk.DEFAULT_TY, tx: int = _rbk.DEFAULT_TX):
    """Separable convex RBF estimate (global sigma/radius hot path)."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.shepard_refine_global_ref(field, sigma=sigma, radius=radius)
    return _rbk.shepard_refine_global(field, sigma=sigma, radius=radius,
                                      ty=ty, tx=tx, interpret=_interp(backend))
