"""Public jit'd wrappers for the Pallas kernels.

Every op takes ``backend=`` with three settings:
  * "pallas"     — pl.pallas_call compiled for TPU (the production path)
  * "interpret"  — same kernel body, interpreted on CPU (validation path;
                   the default in this CPU container)
  * "jnp"        — the pure-jnp oracle from kernels/ref.py

Wrappers own all padding/unpadding so callers see natural shapes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import cp_detect as _cpk
from repro.kernels import extrema_restore as _exk
from repro.kernels import rbf_refine as _rbk
from repro.kernels import szp_quant as _sqk
from repro.kernels import ref as _ref
from repro.utils import pad_to_multiple

DEFAULT_BACKEND = "interpret"


def _interp(backend: str) -> bool:
    if backend not in ("pallas", "interpret", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend == "interpret"


def szp_quant(xb: jnp.ndarray, eb: float, backend: str = DEFAULT_BACKEND,
              tb: int = _sqk.DEFAULT_TB):
    """Fused QZ+LZ over (B, K) blocks -> (first, mags, signs, widths)."""
    if backend == "jnp":
        return _ref.szp_quant_blocks_ref(xb, eb)
    b = xb.shape[0]
    tb = min(tb, b) if b % min(tb, b) == 0 else tb
    xp = pad_to_multiple(xb, tb, axis=0)
    first, mags, signs, widths = _sqk.szp_quant_blocks(
        xp, eb, tb=tb, interpret=_interp(backend))
    return first[:b], mags[:b], signs[:b], widths[:b]


def szp_dequant(first, mags, signs, eb: float,
                backend: str = DEFAULT_BACKEND, tb: int = _sqk.DEFAULT_TB):
    """Inverse of szp_quant -> (B, K) float32 reconstruction."""
    if backend == "jnp":
        return _ref.szp_dequant_blocks_ref(first, mags, signs, eb)
    b = first.shape[0]
    fp = pad_to_multiple(first, tb, axis=0)
    mp = pad_to_multiple(mags, tb, axis=0)
    sp = pad_to_multiple(signs, tb, axis=0)
    out = _sqk.szp_dequant_blocks(fp, mp, sp, eb, tb=tb,
                                  interpret=_interp(backend))
    return out[:b]


def cp_detect(field: jnp.ndarray, backend: str = DEFAULT_BACKEND,
              ty: int = _cpk.DEFAULT_TY, tx: int = _cpk.DEFAULT_TX):
    """Critical point classification -> int32 labels."""
    if backend == "jnp":
        return _ref.cp_detect_ref(field)
    return _cpk.cp_detect(field, ty=ty, tx=tx, interpret=_interp(backend))


def extrema_restore(recon, labels, cur_labels, ranks, eb: float,
                    backend: str = DEFAULT_BACKEND,
                    ty: int = _exk.DEFAULT_TY, tx: int = _exk.DEFAULT_TX):
    """Fused lost-extrema restoration -> corrected field."""
    if backend == "jnp":
        return _ref.extrema_restore_ref(recon, labels, cur_labels, ranks, eb)
    return _exk.extrema_restore(recon, labels, cur_labels, ranks, eb,
                                ty=ty, tx=tx, interpret=_interp(backend))


def shepard_refine(field: jnp.ndarray, sigma: float = 0.75, radius: int = 2,
                   backend: str = DEFAULT_BACKEND,
                   ty: int = _rbk.DEFAULT_TY, tx: int = _rbk.DEFAULT_TX):
    """Separable convex RBF estimate (global sigma/radius hot path)."""
    if backend == "jnp":
        return _ref.shepard_refine_global_ref(field, sigma=sigma, radius=radius)
    return _rbk.shepard_refine_global(field, sigma=sigma, radius=radius,
                                      ty=ty, tx=tx, interpret=_interp(backend))
