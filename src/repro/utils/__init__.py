"""Small shared helpers used across the repro framework."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    """Ceiling division for Python ints (static shapes)."""
    return -(-a // b)


def pad_to_multiple(x: jnp.ndarray, multiple: int, axis: int = 0,
                    mode: str = "edge") -> jnp.ndarray:
    """Pad ``x`` along ``axis`` so its length is a multiple of ``multiple``.

    ``mode='edge'`` replicates the final element so that block-delta streams
    see zero deltas in the padding region (maximally compressible).
    """
    n = x.shape[axis]
    target = cdiv(n, multiple) * multiple
    if target == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad, mode=mode)


def bitwidth(m: jnp.ndarray, max_bits: int = 32) -> jnp.ndarray:
    """Number of bits needed to represent unsigned magnitudes ``m``.

    bitwidth(0) == 0, bitwidth(1) == 1, bitwidth(2..3) == 2, ...
    Branch-free: counts how many powers of two are <= m.
    """
    m = m.astype(jnp.uint32)
    thresh = (jnp.uint32(1) << jnp.arange(max_bits, dtype=jnp.uint32))
    return (m[..., None] >= thresh).sum(axis=-1).astype(jnp.int32)


def exclusive_cumsum(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    inc = jnp.cumsum(x, axis=axis)
    return inc - x


# --- monotone IEEE-754 <-> sortable-int mapping (for ULP arithmetic) -------

def float_to_ordered_int(x: jnp.ndarray) -> jnp.ndarray:
    """Map float32 -> int32 such that the int order equals the float order.

    Standard trick: for negative floats flip all bits, for positive set the
    sign bit. Total order matches IEEE-754 (with -0.0 < +0.0 collapsing to
    adjacent codes, which is harmless for our strict-inequality use).
    """
    i = x.astype(jnp.float32).view(jnp.int32)
    int32_min = jnp.int32(-(2 ** 31))
    return jnp.where(i < 0, int32_min - i, i)


def ordered_int_to_float(i: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`float_to_ordered_int`."""
    int32_min = jnp.int32(-(2 ** 31))
    raw = jnp.where(i < 0, int32_min - i, i)
    return raw.view(jnp.float32)


def ulp_step(x: jnp.ndarray, steps: jnp.ndarray) -> jnp.ndarray:
    """Move ``x`` by ``steps`` representable float32 values (monotone).

    steps > 0 moves up, steps < 0 moves down.  This realizes the paper's
    "delta times machine epsilon" stencil offset exactly (see DESIGN.md).
    """
    return ordered_int_to_float(float_to_ordered_int(x) + steps.astype(jnp.int32))


def np_bytes_concat(arrays) -> bytes:
    """Serialize a list of numpy arrays to a flat byte string."""
    return b"".join(np.asarray(a).tobytes() for a in arrays)
