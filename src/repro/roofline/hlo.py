"""HLO-text parsing: collective byte accounting for the roofline.

cost_analysis() has no collective term, so we parse the compiled module:
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute contributes its result-tensor bytes to its category.
Ring-algorithm wire factors (x2(N-1)/N for all-reduce, (N-1)/N for
gather/scatter) are applied separately by the roofline so both raw and
effective numbers are visible.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_text(hlo: str) -> Dict[str, float]:
    """Sum result bytes per collective category over an HLO module dump."""
    out = defaultdict(float)
    counts = defaultdict(int)
    for m in _OP_RE.finditer(hlo):
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            nbytes = sum(_shape_bytes(dt, dm)
                         for dt, dm in _TUPLE_ELEM_RE.findall(tuple_body))
        else:
            nbytes = _shape_bytes(dtype, dims)
        # -start/-done pairs would double count: only count -start or plain
        if "-done(" in m.group(0):
            continue
        out[kind] += nbytes
        counts[kind] += 1
    rec = {k: float(v) for k, v in out.items()}
    rec["total"] = float(sum(out.values()))
    rec["counts"] = dict(counts)
    return rec


def while_trip_counts(hlo: str) -> Dict[str, int]:
    """Best-effort trip counts of while loops (scan over layer groups)."""
    # XLA annotates: while(...), ... trip_count=N in backend_config or
    # induction-variable comments; fall back to empty.
    out = {}
    for m in re.finditer(r'"known_trip_count":\{"n":"(\d+)"\}', hlo):
        out[f"while_{len(out)}"] = int(m.group(1))
    return out
