"""Roofline analysis: three terms per (arch x shape) cell from the dry-run.

Hardware model (TPU v5e, per chip):
    peak bf16     = 197 TFLOP/s
    HBM bandwidth = 819 GB/s
    ICI link      = ~50 GB/s

Terms (seconds, per step, all per-chip — the dry-run's cost_analysis and
HLO collective parse are per-device SPMD numbers):
    compute    = HLO_FLOPs / peak
    memory     = HLO_bytes / HBM_bw
    collective = wire_bytes / link_bw   (ring factors: AR x2, AG/RS/A2A x1)

HLO_FLOPs/bytes come from the *costing* compiles (scan bodies unrolled at
depth 1 and 2, linearly extrapolated to full depth — XLA's cost analysis
counts while bodies once, measured in EXPERIMENTS.md §Dry-run).  The RWKV
time-scan stays sequential even in costing compiles; its recurrence FLOPs
are added analytically (exact op count of the step body).

MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (prefill/decode), with
N_active excluding embeddings and counting only routed-active experts.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# --------------------------------------------------------------------------
# analytic model flops
# --------------------------------------------------------------------------

def _param_counts(cfg):
    """(total, active_nonembed) parameter counts from the config."""
    import jax
    from repro.models import lm
    sds = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    total = sum(int(math.prod(x.shape)) for x in jax.tree.leaves(sds))
    embed = cfg.padded_vocab * cfg.d_model
    if not cfg.tie_embeddings:
        embed *= 2
    nonembed = total - embed
    if cfg.num_experts > 0:
        expert = 3 * cfg.d_model * cfg.d_ff * cfg.num_experts \
            * cfg.num_layers
        active = 3 * cfg.d_model * cfg.d_ff * cfg.top_k * cfg.num_layers
        nonembed = nonembed - expert + active
    return total, nonembed


def model_flops(cfg, shape, n_tokens: Optional[int] = None) -> float:
    """6*N*D train / 2*N*D inference (global, all chips)."""
    _, n_active = _param_counts(cfg)
    if shape.mode == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.mode == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    toks = shape.global_batch * 1
    return 2.0 * n_active * toks


def rwkv_scan_flops(cfg, shape) -> float:
    """Analytic WKV recurrence FLOPs (global) missed by costing compiles."""
    if "rwkv" not in cfg.layer_pattern:
        return 0.0
    dh = cfg.rwkv_head_dim
    h = cfg.d_model // dh
    toks = shape.global_batch * (shape.seq_len if shape.mode != "decode"
                                 else 1)
    per_tok_layer = 7.0 * h * dh * dh          # kv, u*kv, r., w*S, +
    mult = 3.0 if shape.mode == "train" else 1.0   # fwd+bwd(~2x)
    return per_tok_layer * toks * cfg.num_layers * mult


# --------------------------------------------------------------------------
# record analysis
# --------------------------------------------------------------------------

@dataclass
class RooflineRow:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    note: str


def wire_bytes(coll: Dict[str, float]) -> float:
    """Ring-factor-weighted wire bytes from the per-category parse."""
    return (2.0 * coll.get("all-reduce", 0.0)
            + coll.get("all-gather", 0.0)
            + coll.get("reduce-scatter", 0.0)
            + coll.get("all-to-all", 0.0)
            + coll.get("collective-permute", 0.0))


def analyze_record(rec: dict, cfg=None) -> RooflineRow:
    from repro.configs.base import SHAPES
    from repro.models import registry
    cfg = cfg or registry.get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]

    costing = rec.get("costing") or {}
    cost = costing.get("cost") or rec["cost"]
    coll = costing.get("collectives") or {
        k: v for k, v in rec["collectives"].items() if k != "counts"}

    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    flops_dev += rwkv_scan_flops(cfg, shape) / n_dev

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_bytes(coll) / LINK_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    mf_dev = mf / n_dev
    useful = mf_dev / flops_dev if flops_dev else 0.0

    note = _note(bottleneck, rec, useful)
    return RooflineRow(rec["arch"], rec["shape"], compute_s, memory_s,
                       collective_s, bottleneck, mf_dev, flops_dev, useful,
                       note)


def _note(bottleneck: str, rec: dict, useful: float) -> str:
    if bottleneck == "collective":
        return ("shrink resharding traffic: fewer all-gathers per layer "
                "(sequence-parallel k/v, compressed DP all-reduce)")
    if bottleneck == "memory":
        return ("raise arithmetic intensity: larger per-chip batch, fuse "
                "elementwise chains, bf16 cache reads")
    if useful < 0.5:
        return "cut redundant compute: remat policy / attention masking"
    return "near compute roof: only kernel-level fusion is left"


def roofline_fraction(row: RooflineRow) -> float:
    """Achievable fraction of compute roof if terms overlap perfectly:
    compute / max(all terms)."""
    worst = max(row.compute_s, row.memory_s, row.collective_s)
    return row.compute_s / worst if worst else 0.0


def load_records(results_dir: str = RESULTS_DIR, mesh: str = "single"):
    out = []
    if not os.path.isdir(results_dir):
        return out
    for fn in sorted(os.listdir(results_dir)):
        if fn.endswith(f"__{mesh}.json"):
            with open(os.path.join(results_dir, fn)) as f:
                out.append(json.load(f))
    return out


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "bottleneck | roofline frac | 6ND/HLO | next move |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r.arch} | {r.shape} | {r.compute_s * 1e3:.2f} | "
                 f"{r.memory_s * 1e3:.2f} | {r.collective_s * 1e3:.2f} | "
                 f"**{r.bottleneck}** | {roofline_fraction(r):.2f} | "
                 f"{r.useful_ratio:.2f} | {r.note} |\n")
    return hdr + body


def main():
    from repro.models import registry
    recs = load_records()
    rows = [analyze_record(r) for r in recs]
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
