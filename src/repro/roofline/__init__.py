from repro.roofline.hlo import collective_bytes_from_text, while_trip_counts

__all__ = ["collective_bytes_from_text", "while_trip_counts"]
