"""Deterministic, seedable fault injection for the fault-tolerance layer.

The checkpoint commit protocol, the async writer, and the training loop
are only trustworthy if every failure mode they claim to survive can be
*produced on demand*.  This module is that switchboard: production code
calls :func:`fire` / :func:`mangle` at named **sites**, and a test (or a
chaos CI leg) installs a :class:`FaultPlan` mapping sites to faults.
With no plan installed — the production default — every hook is a single
module-global ``None`` read and returns immediately.

Injectable faults (kind / canonical site):

  * ``crash``        — a process dies at the site.  Raises
    :class:`InjectedCrash` (thread-level "kill": the write aborts leaving
    whatever is already on disk), or ``hard=True`` calls ``os._exit`` for
    subprocess tests that need a true no-cleanup kill.  Canonical sites:
    ``ckpt.before_barrier`` (blob written, ready marker not yet),
    ``ckpt.before_manifest`` (committer merged, manifest not yet).
  * ``error``        — raises ``exc(message)`` (default ``OSError``) the
    first ``times`` hits: the transient-IO fault the async writer's retry
    loop must absorb.  Canonical site: ``ckpt.write``.
  * ``torn``         — :func:`mangle` corrupts bytes on their way to disk
    (bit-flip or truncation) while the manifest keeps the hash of the
    INTENDED bytes — a torn write the restore-time hash check must catch.
    Canonical site: ``ckpt.blob``.
  * ``device_loss``  — raises :class:`repro.dist.elastic.DeviceLoss` at
    step ``at`` (``keep`` = how many devices survive): the event the
    train loop's mid-run elastic recovery handles.  Canonical site:
    ``loop.step``.

Faults are deterministic: ``at`` pins a fault to one step, ``times``
bounds firings, and probabilistic faults (``prob < 1``) draw from a
``numpy`` generator seeded by the plan — the same plan replays the same
fault sequence.

Usage::

    from repro import faults

    plan = faults.FaultPlan({
        "ckpt.write": faults.Fault("error", times=2),      # 2 transient IO
        "loop.step": faults.Fault("device_loss", at=7, keep=4),
    })
    with faults.injected(plan):
        ...   # run the loop; plan.fired records what actually hit

The ``REPRO_FAULTS=1`` CI leg runs the chaos suite (tests/test_faults.py,
tests/test_ckpt_coord.py) with plans installed per test.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

KINDS = ("crash", "error", "torn", "device_loss")


class InjectedFault(RuntimeError):
    """Base class for every injected failure (so tests can catch broadly)."""


class InjectedCrash(InjectedFault):
    """A simulated process kill at an injection site."""


@dataclass
class Fault:
    """One injectable fault bound to a site by :class:`FaultPlan`.

    Args:
      kind:    'crash' | 'error' | 'torn' | 'device_loss'.
      at:      only fire when the site reports ``step == at`` (None = any).
      times:   maximum number of firings (None = unlimited).
      prob:    per-hit firing probability (drawn from the plan's seeded rng).
      exc:     exception type for ``kind='error'``.
      message: message carried by the raised exception.
      hard:    ``kind='crash'``: ``os._exit(13)`` instead of raising —
               a true no-cleanup kill for subprocess tests.
      keep:    ``kind='device_loss'``: how many devices survive.
      torn:    ``kind='torn'``: 'flip' (XOR a span) or 'truncate'.
      nbytes:  ``kind='torn'``: how many bytes to flip / chop.
    """
    kind: str
    at: Optional[int] = None
    times: Optional[int] = 1
    prob: float = 1.0
    exc: type = OSError
    message: str = "injected fault"
    hard: bool = False
    keep: Optional[int] = None
    torn: str = "flip"
    nbytes: int = 64

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {KINDS})")


class FaultPlan:
    """Site -> :class:`Fault` map with deterministic firing bookkeeping.

    ``fired`` records every (site, ctx) that actually hit, in order —
    tests assert against it.  Thread-safe: the async writer thread and
    the step loop may both hit sites concurrently.
    """

    def __init__(self, sites: Dict[str, Fault], seed: int = 0) -> None:
        import numpy as np
        self.sites = dict(sites)
        self._left = {s: f.times for s, f in self.sites.items()}
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, Dict[str, Any]]] = []

    def _matches(self, site: str, ctx: Dict[str, Any]) -> Optional[Fault]:
        f = self.sites.get(site)
        if f is None:
            return None
        with self._lock:
            if f.at is not None and ctx.get("step") != f.at:
                return None
            left = self._left[site]
            if left is not None and left <= 0:
                return None
            if f.prob < 1.0 and float(self._rng.random()) >= f.prob:
                return None
            if left is not None:
                self._left[site] = left - 1
            self.fired.append((site, dict(ctx)))
        return f

    def fire(self, site: str, **ctx: Any) -> None:
        f = self._matches(site, ctx)
        if f is None or f.kind == "torn":
            return
        if f.kind == "crash":
            if f.hard:
                os._exit(13)
            raise InjectedCrash(f"{f.message} at {site} ({ctx})")
        if f.kind == "error":
            raise f.exc(f"{f.message} at {site} ({ctx})")
        # device_loss
        from repro.dist.elastic import DeviceLoss
        raise DeviceLoss(f"{f.message} at {site} ({ctx})", keep=f.keep)

    def mangle(self, site: str, data: bytes, **ctx: Any) -> bytes:
        f = self.sites.get(site)
        if f is None or f.kind != "torn":
            return data
        f = self._matches(site, ctx)
        if f is None:
            return data
        if f.torn == "truncate":
            return data[: max(0, len(data) - min(f.nbytes, len(data)))]
        off = len(data) // 2
        span = min(f.nbytes, len(data) - off)
        torn = bytearray(data)
        for i in range(span):
            torn[off + i] ^= 0xFF
        return bytes(torn)


# -- module-global hook: production paths pay exactly one read of _PLAN ----

_PLAN: Optional[FaultPlan] = None


def active() -> Optional[FaultPlan]:
    return _PLAN


def install(plan: FaultPlan) -> None:
    global _PLAN
    _PLAN = plan


def clear() -> None:
    global _PLAN
    _PLAN = None


@contextmanager
def injected(plan: FaultPlan):
    """Install ``plan`` for the duration of the block (always cleared)."""
    install(plan)
    try:
        yield plan
    finally:
        clear()


def fire(site: str, **ctx: Any) -> None:
    """Raise the configured fault for ``site`` (no-op with no plan)."""
    if _PLAN is not None:
        _PLAN.fire(site, **ctx)


def mangle(site: str, data: bytes, **ctx: Any) -> bytes:
    """Return ``data`` as it will land on disk (torn when configured)."""
    if _PLAN is None:
        return data
    return _PLAN.mangle(site, data, **ctx)
