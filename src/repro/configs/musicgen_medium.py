"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only transformer over
EnCodec tokens.  48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.

The EnCodec frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model); labels are codebook ids."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=("global",),
    act="gelu",
    frontend="audio_frames",
    sharding_strategy="fsdp",    # §Perf: train-only FSDP (5.8x, minicpm cell)
    source="arXiv:2306.05284; hf facebook/musicgen-medium "
           "(RoPE used in place of sinusoidal positions — noted deviation)",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=64, attn_chunk=32, loss_chunk=16,
                          remat=False)
