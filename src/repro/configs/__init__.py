"""Per-architecture configs (one module per assigned arch) + shape registry."""
from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES,
                                LONG_CONTEXT_ARCHS, runnable_cells)

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "LONG_CONTEXT_ARCHS",
           "runnable_cells"]
