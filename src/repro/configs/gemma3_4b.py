"""Gemma-3 4B [hf:google/gemma-3-*-pt; unverified] — 5:1 local:global
attention, qk-norm, dual RoPE theta.  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_4b",
    family="dense",
    num_layers=34,          # 5 x (5 local + 1 global) + 4 local tail
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    qk_norm=True,
    rope_theta=1e6,          # global layers
    rope_theta_local=1e4,    # local layers
    act="geglu",
    scale_embed=True,
    tie_embeddings=True,
    source="hf google/gemma-3-1b-pt family (unverified tier)",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=7, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, window_size=16, attn_chunk=16,
                          loss_chunk=16, remat=False)
