"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf] — attention-free, data-dependent
decay.  32L d_model=2560 d_ff=8960 vocab=65536, head size 64."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,           # 2560 / head size 64
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    layer_pattern=("rwkv",),
    rwkv_head_dim=64,
    rwkv_lora=32,
    act="relu",             # channel-mix uses squared relu internally
    rwkv_impl="chunked",    # §Perf: matmul-form WKV (13.5x w/ fsdp)
    sharding_strategy="fsdp",   # §Perf: train-only FSDP
    source="arXiv:2404.05892 (Finch); hf RWKV/rwkv-6-world-3b",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, rwkv_head_dim=16,
                          rwkv_lora=8, d_ff=128, vocab_size=128,
                          attn_chunk=32, loss_chunk=16, remat=False)
