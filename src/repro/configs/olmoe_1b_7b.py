"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64 experts top-8 MoE.
16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe_1b_7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    layer_pattern=("global",),
    num_experts=64,
    top_k=8,
    act="swiglu",
    moe_impl="shard_map",        # §Perf: manual EP (82x dominant-term win)
    sharding_strategy="fsdp",    # §Perf: train-only FSDP
    source="arXiv:2409.02060; hf allenai/OLMoE-1B-7B-0924",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=64,
                          vocab_size=128, num_experts=8, top_k=2,
                          attn_chunk=32, loss_chunk=16, remat=False)
