"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf] — RG-LRU + local
attention, pattern (recurrent, recurrent, local).  26L d_model=2560 10H
(MQA kv=1) d_ff=7680 vocab=256000."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma_2b",
    family="hybrid",
    num_layers=26,          # 8 x (rec, rec, local) + (rec, rec) tail
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,         # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("recurrent", "recurrent", "local"),
    window_size=2048,
    rnn_width=2560,
    act="geglu",
    scale_embed=True,
    tie_embeddings=True,
    source="arXiv:2402.19427 (Griffin); hf google/recurrentgemma-2b",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=4, d_model=64, num_heads=4,
                          num_kv_heads=1, head_dim=16, d_ff=128,
                          vocab_size=128, rnn_width=64, window_size=16,
                          attn_chunk=16, loss_chunk=16, remat=False)
