"""InternVL2-76B [arXiv:2404.16821; unverified] — InternViT + LLM backbone.
The assignment specifies the transformer BACKBONE (llama-3-70B-like):
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

The InternViT vision frontend is a STUB per the assignment: input_specs()
provides 256 precomputed patch embeddings prepended to the token stream."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    layer_pattern=("global",),
    act="swiglu",
    frontend="vision_patches",
    num_prefix_embeds=256,
    fsdp=True,               # 76B params: shard weights over data axis too
    source="arXiv:2404.16821 (unverified tier)",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, num_prefix_embeds=8,
                          attn_chunk=32, loss_chunk=16, fsdp=False,
                          remat=False)
