"""Architecture + shape configuration system.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact published sizes) and ``smoke_config()`` (reduced same-family
config for CPU tests).  Shapes are the four assigned input regimes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # layer pattern, cycled through the depth; entries:
    #   'global' | 'local' (windowed) | 'recurrent' (RG-LRU) | 'rwkv'
    layer_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 4096
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_theta_local: Optional[float] = None
    act: str = "swiglu"               # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embed: bool = False         # gemma multiplies embed by sqrt(d)
    # MoE
    num_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False      # arctic: dense FFN parallel to MoE
    capacity_factor: float = 1.25
    renormalize_router: bool = True
    router_aux_weight: float = 0.01
    moe_dense_ff: int = 0             # hidden of the parallel dense FFN
    # recurrent
    rnn_width: int = 0                # RG-LRU width (0 -> d_model)
    rwkv_head_dim: int = 64
    rwkv_lora: int = 32
    # frontend stubs
    frontend: Optional[str] = None    # audio_frames | vision_patches
    num_prefix_embeds: int = 0        # vlm: patch embeddings prepended
    # numerics / parallelism
    param_dtype: jnp.dtype = jnp.bfloat16
    activation_dtype: jnp.dtype = jnp.bfloat16
    attn_chunk: int = 1024
    loss_chunk: int = 512
    fsdp: bool = False                # shard params over the data axis too
    remat: bool = True                # checkpoint each layer group
    # §Perf strategy knobs (hillclimbed in EXPERIMENTS.md)
    moe_impl: str = "einsum"          # einsum (GSPMD) | shard_map (manual EP)
    sharding_strategy: str = "tp"     # tp | fsdp (pure-DP activations,
                                      #   fully sharded params/optimizer)
    rwkv_impl: str = "scan"           # scan | chunked (matmul-form WKV)
    grad_compress: bool = False       # hZCCL-style quantized DP all-reduce
    grad_topo_frac: float = 0.0       # TopoSZp protected top-|g| tail frac
                                      #   (0 = plain compressed psum)
    grad_wire_format: str = "int32"   # "int32" (code psum, accounting-only
                                      #   byte win) | "packed" (dist.ring
                                      #   bitpacked ppermute ring all-reduce)
    # TopoSZp kernel dispatch (core/szp.py, core/toposzp.py, ckpt blobs):
    #   auto (pallas on TPU, jnp elsewhere) | pallas | interpret | jnp
    kernel_backend: str = "auto"
    # checkpointing (repro.ckpt v2: sharded blobs + async writer)
    ckpt_mode: str = "raw"            # raw | szp | toposzp leaf mode for
                                      #   large f32 (optimizer/master) leaves
    ckpt_eb: float = 1e-4             # absolute error bound for lossy modes
    ckpt_async: bool = True           # background serialize+fsync (the step
                                      #   loop only pays the host snapshot)
    # observability (repro.obs): zero-sync spans/counters across compress,
    # serve, ring, and checkpoint paths; also on via REPRO_OBS=1 or
    # launch.train --obs
    obs: bool = False
    # costing mode (roofline): scans counted once by XLA cost analysis, so
    # the dry-run lowers small-depth UNROLLED variants and extrapolates.
    unroll_groups: bool = False
    unroll_loss: bool = False
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for clean model-axis sharding."""
        v = self.vocab_size
        return -(-v // 256) * 256

    def pattern_layers(self) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
        """(scanned groups x pattern, unrolled tail) covering num_layers."""
        p = self.layer_pattern
        n_groups, tail = divmod(self.num_layers, len(p))
        return tuple(p for _ in range(n_groups)), tuple(p[:tail])

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str          # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing; DESIGN.md
# §long_500k applicability documents the skips)
LONG_CONTEXT_ARCHS = ("rwkv6_3b", "recurrentgemma_2b")


def runnable_cells(arch_names):
    """All (arch, shape) cells honoring the documented long_500k skips."""
    cells = []
    for a in arch_names:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                continue
            cells.append((a, s))
    return cells
