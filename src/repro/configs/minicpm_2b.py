"""MiniCPM-2B [arXiv:2404.06395; hf] — llama-like dense with WSD schedule.
40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753 (padded to 122880)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm_2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    layer_pattern=("global",),
    act="swiglu",
    tie_embeddings=True,    # MiniCPM ties input/output embeddings
    sharding_strategy="fsdp",    # §Perf: train-only FSDP (5.8x, minicpm cell)
    source="arXiv:2404.06395; hf openbmb/MiniCPM-2B (WSD schedule in optim/)",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=128, attn_chunk=32, loss_chunk=16,
                          remat=False)
