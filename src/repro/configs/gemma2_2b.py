"""Gemma-2 2B [arXiv:2408.00118; hf] — alternating local:global attention,
logit soft-capping.  26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2_2b",
    family="dense",
    num_layers=26,          # 13 x (local, global)
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=("local", "global"),
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="geglu",
    scale_embed=True,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf google/gemma-2-2b",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=4, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=128, window_size=16, attn_chunk=16,
                          loss_chunk=16, remat=False)
