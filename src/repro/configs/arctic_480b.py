"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: 128 experts top-2 with a parallel dense residual FFN.
35L d_model=7168 56H (GQA kv=8) expert d_ff=4864 vocab=32000."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic_480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    layer_pattern=("global",),
    num_experts=128,
    top_k=2,
    dense_residual=True,     # Arctic's dense-MoE hybrid
    moe_dense_ff=4864,
    act="swiglu",
    fsdp=True,               # 480B params: shard weights over data axis too
    moe_impl="shard_map",        # §Perf: manual EP (olmoe cell, 69.8x)
    source="hf Snowflake/snowflake-arctic-base",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, head_dim=16, d_ff=96,
                          moe_dense_ff=96, vocab_size=128, num_experts=8,
                          top_k=2, attn_chunk=32, loss_chunk=16,
                          fsdp=False, remat=False)
