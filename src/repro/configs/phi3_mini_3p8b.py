"""Phi-3-mini 3.8B [arXiv:2404.14219] — dense RoPE SwiGLU.
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3_mini_3p8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    layer_pattern=("global",),
    act="swiglu",
    source="arXiv:2404.14219 (unverified tier)",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=128, attn_chunk=32, loss_chunk=16,
                          remat=False)
