"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model); the 'pod' axis carries
the data-parallel dimension across the inter-pod links (DCN on real
hardware), which the dry-run proves shards correctly.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 4, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CPU tests (requires forced host device count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
