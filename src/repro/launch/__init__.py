"""Launchers: mesh construction, multi-pod dry-run, end-to-end training."""
