import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb driver: re-lower a cell with strategy overrides and
report the three roofline terms (hypothesis -> change -> measure loop).

  python -m repro.launch.hillclimb --arch olmoe_1b_7b --shape train_4k \\
      --set moe_impl=shard_map
"""
import argparse
import json

from repro.configs.base import SHAPES
from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh
from repro.models import registry, set_active_mesh
from repro.roofline import analysis as ra


def measure(arch: str, shape: str, overrides: dict, tag: str,
            save: bool = True):
    cfg = registry.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    rec = dryrun.run_cell(arch, shape, multi_pod=False, cfg=cfg,
                          extra_tag=f"__{tag}" if tag else "",
                          save=save, costing=True)
    row = ra.analyze_record(rec, cfg=cfg)
    print(f"[hillclimb] {arch} x {shape} [{tag or 'baseline'}] "
          f"compute={row.compute_s * 1e3:.1f}ms "
          f"memory={row.memory_s * 1e3:.1f}ms "
          f"collective={row.collective_s * 1e3:.1f}ms "
          f"bottleneck={row.bottleneck} "
          f"frac={ra.roofline_fraction(row):.3f} "
          f"6ND/HLO={row.useful_ratio:.2f}", flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v
    tag = args.tag or "_".join(f"{k}-{v}" for k, v in overrides.items())
    measure(args.arch, args.shape, overrides, tag)


if __name__ == "__main__":
    main()
