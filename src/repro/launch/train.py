"""End-to-end training driver.

CPU-runnable at reduced scale (smoke configs) and the same code path the
production mesh would launch:

  PYTHONPATH=src python -m repro.launch.train --arch minicpm_2b --smoke \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ck --grad-compress
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import faults, obs
from repro.ckpt import CheckpointManager
from repro.data import token_batches
from repro.dist.compat import HAS_PARTIAL_AUTO
from repro.launch.mesh import make_test_mesh
from repro.models import lm, registry, set_active_mesh
from repro.optim import adamw, wsd
from repro.train import init_state, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm_2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-mode", choices=["raw", "szp", "toposzp"],
                    default=None,
                    help="v2 leaf mode for large f32 leaves: raw bytes, "
                         "error-bounded SZp, or TopoSZp (critical points "
                         "and rank order exact under a 2*eb bound); unset "
                         "defers to cfg.ckpt_mode")
    ap.add_argument("--ckpt-eb", type=float, default=None,
                    help="absolute error bound for lossy checkpoint modes; "
                         "unset defers to cfg.ckpt_eb")
    ap.add_argument("--ckpt-sync", action="store_true",
                    help="serialize+fsync on the step loop thread instead "
                         "of the async background writer")
    ap.add_argument("--max-recoveries", type=int, default=0,
                    help="how many mid-run device-loss events the loop "
                         "absorbs by rolling back to the last committed "
                         "checkpoint and rebuilding the mesh (0 = crash, "
                         "the pre-elastic behavior)")
    ap.add_argument("--barrier-timeout", type=float, default=None,
                    metavar="S",
                    help="coordinated-commit barrier timeout in seconds "
                         "(multi-process saves; default 120)")
    ap.add_argument("--inject-device-loss", default=None,
                    metavar="STEP[:KEEP]",
                    help="fault injection: raise a DeviceLoss at STEP, "
                         "keeping the first KEEP devices (default: all, "
                         "i.e. a soft restart); exercises the elastic "
                         "recovery path end to end")
    ap.add_argument("--kernel-backend",
                    choices=["auto", "pallas", "interpret", "jnp"],
                    default=None,
                    help="TopoSZp kernel dispatch for lossy checkpoint "
                         "blobs (core/szp, core/toposzp): auto picks "
                         "pallas on TPU and the jnp oracle elsewhere; "
                         "unset defers to cfg.kernel_backend")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--rel-eb", type=float, default=1e-4)
    ap.add_argument("--topo-frac", type=float, default=None,
                    help="protected top-|g| tail fraction (TopoSZp-aware "
                         "collective); 0 forces the plain compressed psum, "
                         "unset defers to cfg.grad_topo_frac")
    ap.add_argument("--wire-format", choices=["int32", "packed"],
                    default=None,
                    help="compressed-collective wire: int32 code psum or "
                         "the dist.ring bitpacked ppermute ring all-reduce; "
                         "unset defers to cfg.grad_wire_format")
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", action="store_true",
                    help="enable repro.obs (zero-sync spans/counters; "
                         "periodic [obs] lines every log_every steps); "
                         "also on via REPRO_OBS=1 or cfg.obs")
    ap.add_argument("--obs-trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON (open at "
                         "ui.perfetto.dev) on exit; implies --obs")
    ap.add_argument("--obs-jsonl", default=None, metavar="PATH",
                    help="stream obs span/error events to PATH as JSON "
                         "lines; implies --obs")
    args = ap.parse_args()

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if args.obs or args.obs_trace or args.obs_jsonl or cfg.obs:
        obs.enable()
    if args.obs_jsonl:
        obs.configure(jsonl=args.obs_jsonl)
    mesh = None
    if args.data_parallel * args.model_parallel > 1:
        mesh = make_test_mesh(args.data_parallel, args.model_parallel)
        # Legacy XLA runs the compressed-DP step fully manual (see
        # dist.compat.HAS_PARTIAL_AUTO); the models' 'model'-axis
        # sharding constraints are illegal inside that manual context,
        # so leave the active mesh unset there (model-axis compute is
        # replicated per DP shard, which is the documented degradation).
        if not args.grad_compress or HAS_PARTIAL_AUTO:
            set_active_mesh(mesh)

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    print(f"[train] arch={cfg.name} params={lm.param_count(params):,}")
    optimizer = adamw(wsd(args.lr, warmup=max(args.steps // 10, 1),
                          stable=args.steps // 2, decay=args.steps // 2))
    state = init_state(params, optimizer, args.grad_compress)
    step_fn = make_train_step(cfg, optimizer, mesh=mesh,
                              grad_compress=args.grad_compress,
                              rel_eb=args.rel_eb,
                              topo_frac=args.topo_frac,
                              wire_format=args.wire_format)

    def batches():
        for b in token_batches(cfg, args.batch, args.seq, seed=args.seed,
                               start_step=int(state.step)):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    manager = None
    if args.ckpt_dir is not None:
        mgr_kw = {}
        if args.barrier_timeout is not None:
            mgr_kw["barrier_timeout_s"] = args.barrier_timeout
        manager = CheckpointManager(
            args.ckpt_dir,
            mode=args.ckpt_mode if args.ckpt_mode is not None
            else cfg.ckpt_mode,
            eb=args.ckpt_eb if args.ckpt_eb is not None else cfg.ckpt_eb,
            async_write=cfg.ckpt_async and not args.ckpt_sync,
            kernel_backend=args.kernel_backend if args.kernel_backend
            is not None else cfg.kernel_backend, **mgr_kw)

    if args.inject_device_loss is not None:
        step_s, _, keep_s = args.inject_device_loss.partition(":")
        faults.install(faults.FaultPlan(sites={
            "loop.step": faults.Fault(
                kind="device_loss", at=int(step_s),
                keep=int(keep_s) if keep_s else None)}))

    def rebuild_step(new_mesh):
        # shard_map steps close over the mesh; rebuild against the one
        # the elastic recovery produced (and point the models at it)
        if not args.grad_compress or HAS_PARTIAL_AUTO:
            set_active_mesh(new_mesh)
        return make_train_step(cfg, optimizer, mesh=new_mesh,
                               grad_compress=args.grad_compress,
                               rel_eb=args.rel_eb,
                               topo_frac=args.topo_frac,
                               wire_format=args.wire_format)

    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        state, report = train_loop(
            state, step_fn, batches(), num_steps=args.steps,
            ckpt_manager=manager, ckpt_every=args.ckpt_every,
            mesh=mesh, model_parallel=args.model_parallel,
            max_recoveries=args.max_recoveries,
            rebuild_step=rebuild_step if args.max_recoveries else None)
    if report.resharded:
        print(f"[train] elastic restore: checkpoint mesh "
              f"{report.saved_mesh} resharded onto {report.restore_mesh}")
    for ev in report.recoveries:
        print(f"[train] recovered from device loss at step {ev['step']}: "
              f"rolled back to {ev['restored_from']}, mesh {ev['mesh']} "
              f"({ev['recovery_s'] * 1e3:.0f} ms)")
    print(f"[train] done: loss {report.losses[0]:.4f} -> "
          f"{report.losses[-1]:.4f} over {report.steps_run} steps; "
          f"stragglers={len(report.straggler_events)}")
    if obs.enabled():
        print("[obs] " + obs.summary_line())
        if args.obs_trace:
            print(f"[obs] chrome trace -> "
                  f"{obs.export_chrome_trace(args.obs_trace)}")


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
