import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA flag above is read at first jax
init).  For every cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. eval_shape's the parameters (ShapeDtypeStruct — zero allocation),
  3. assigns shardings from dist.sharding rules,
  4. jits the right step (train_step / prefill / serve_step) with
     in_shardings/out_shardings, .lower()s with input_specs(), .compile()s,
  5. records memory_analysis(), cost_analysis() and the per-category
     collective byte counts parsed from the compiled HLO,
  6. writes results/dryrun/<cell>.json for the roofline stage.

Usage:
  python -m repro.launch.dryrun --arch gemma2_2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, runnable_cells
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm, registry, set_active_mesh
from repro.models.registry import ARCH_IDS
from repro.optim import adamw, constant
from repro.roofline.hlo import collective_bytes_from_text
from repro.serve.engine import serve_step
from repro.train.state import TrainState

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _cost_dict(cost) -> dict:
    """Normalize compiled.cost_analysis() across JAX versions (older
    releases return a one-element list of dicts, newer a flat dict)."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {k: float(v) for k, v in (cost or {}).items()
            if isinstance(v, (int, float))}


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sc = SHAPES[shape_name]
    b, s = sc.global_batch, sc.seq_len
    sds = jax.ShapeDtypeStruct
    i32, act = jnp.int32, cfg.activation_dtype

    if sc.mode in ("train", "prefill"):
        if cfg.frontend == "audio_frames":
            batch = {"embeds": sds((b, s, cfg.d_model), act),
                     "labels": sds((b, s), i32)}
        elif cfg.frontend == "vision_patches":
            npre = cfg.num_prefix_embeds
            batch = {"patch_embeds": sds((b, npre, cfg.d_model), act),
                     "tokens": sds((b, s - npre), i32)}
        else:
            batch = {"tokens": sds((b, s), i32)}
        return {"batch": batch}

    # decode: one new token against caches of length seq_len
    caches = lm.make_caches(cfg, b, s, spec=True)
    return {"tokens": sds((b, 1), i32), "caches": caches}


def _params_specs(cfg):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def _state_specs(cfg, params_sds, optimizer):
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    err = None
    return TrainState(jax.ShapeDtypeStruct((), jnp.int32), params_sds,
                      opt_sds, err)


def _opt_shardings(opt_sds, param_sh, mesh, cfg):
    """Optimizer state inherits the parameter shardings (master/m/v)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    return type(opt_sds)(rep, param_sh, param_sh, param_sh)


def _lower_cell(cfg, shape_name, mesh):
    """Build the jitted step for one cell and lower it (no compile)."""
    from repro.models.common import set_sharding_strategy
    sc = SHAPES[shape_name]
    # fsdp (pure-DP activations + fully sharded weights) is a training
    # strategy; serving keeps TP so weights stay resident (no per-layer
    # weight gathers on the latency path).
    strategy = cfg.sharding_strategy if sc.mode == "train" else "tp"
    if cfg.sharding_strategy == "fsdp" and sc.mode != "train":
        cfg = cfg.replace(sharding_strategy="tp")
    set_sharding_strategy(strategy)
    optimizer = adamw(constant(1e-4))
    params_sds = _params_specs(cfg)
    param_sh = shd.param_shardings(params_sds, cfg, mesh)
    specs = input_specs(cfg, shape_name)

    if sc.mode == "train":
        gc = getattr(cfg, "grad_compress", False)
        state_sds = _state_specs(cfg, params_sds, optimizer)
        err_sds, err_sh = None, None
        if gc:
            err_sds = params_sds
            err_sh = param_sh
        state_sds = state_sds._replace(err=err_sds)
        state_sh = TrainState(
            shd.replicated(jnp.zeros(()), mesh), param_sh,
            _opt_shardings(state_sds.opt_state, param_sh, mesh, cfg),
            err_sh)
        batch_sh = shd.data_sharding(specs["batch"], mesh,
                                     cfg.sharding_strategy)
        from repro.train.step import make_train_step
        step = make_train_step(cfg, optimizer, mesh=mesh, grad_compress=gc,
                               topo_frac=getattr(cfg, "grad_topo_frac", 0.0),
                               wire_format=getattr(cfg, "grad_wire_format",
                                                   "int32"))
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return jitted.lower(state_sds, specs["batch"])

    if sc.mode == "prefill":
        batch_sh = shd.data_sharding(specs["batch"], mesh,
                                     cfg.sharding_strategy)
        fn = partial(lm.prefill, cfg=cfg)
        # shard the returned caches (logits left to the partitioner)
        out_sds = jax.eval_shape(lambda p, b: fn(p, batch=b), params_sds,
                                 specs["batch"])
        cache_out_sh = shd.cache_shardings(out_sds[1], cfg, mesh)
        jitted = jax.jit(lambda p, b: fn(p, batch=b),
                         in_shardings=(param_sh, batch_sh),
                         out_shardings=(None, cache_out_sh))
        return jitted.lower(params_sds, specs["batch"])

    cache_sh = shd.cache_shardings(specs["caches"], cfg, mesh)
    tok_sh = shd.data_sharding(specs["tokens"], mesh,
                                cfg.sharding_strategy)
    fn = partial(serve_step, cfg=cfg)
    jitted = jax.jit(
        lambda p, t, c: fn(p, tokens=t, caches=c),
        in_shardings=(param_sh, tok_sh, cache_sh),
        out_shardings=(None, None, cache_sh),
        donate_argnums=(2,))
    return jitted.lower(params_sds, specs["tokens"], specs["caches"])


def _grad_wire_model(cfg, mesh, rel_eb: float = 1e-3) -> dict:
    """Analytic compressed-gradient wire model for one train cell.

    The old model costed the compressed wire at ``code_bits`` per value
    only; this one uses the ``topo_wire_bits`` decomposition (quantized
    body + exact sidecar, which ``grad_topo_frac > 0`` adds) and, for
    ``grad_wire_format="packed"``, the ACTUAL packed bytes the ring moves
    per hop (``dist.ring.packed_wire_summary`` — the same buffer sizes
    the compiled HLO's collective-permutes carry).  ``rel_eb`` mirrors
    the ``make_train_step`` default the dry-run lowers with.
    """
    from repro.dist import ring
    from repro.dist.collectives import sidecar_bits
    from repro.dist.sharding import batch_axes

    n_dp = 1
    for a in batch_axes(mesh):
        n_dp *= int(mesh.shape[a])
    topo_frac = getattr(cfg, "grad_topo_frac", 0.0)
    wire_format = getattr(cfg, "grad_wire_format", "int32")
    params_sds = _params_specs(cfg)
    sizes = [int(x.size) for x in jax.tree.leaves(params_sds)]
    body_bits = ring.base_width(rel_eb) + 1       # static bound incl. sign
    body = sum(body_bits * s for s in sizes)
    side = sum(sidecar_bits(s, topo_frac, n_dp) for s in sizes)
    rec = {
        "wire_format": wire_format,
        "rel_eb": rel_eb,
        "topo_frac": topo_frac,
        "n_dp": n_dp,
        "body_bits_per_val": body_bits,
        "body_bits_per_member": body,
        "sidecar_bits_per_member": side,
        "topo_wire_bits_per_member": body + side,
    }
    if wire_format == "packed" and len(batch_axes(mesh)) == 1:
        rec["packed"] = ring.packed_wire_summary(sizes, rel_eb, topo_frac,
                                                 n_dp)
    return rec


def _costing_cfg(cfg, n_groups: int):
    _, tail = cfg.pattern_layers()
    layers = n_groups * len(cfg.layer_pattern) + len(tail)
    return cfg.replace(num_layers=layers, unroll_groups=True,
                       unroll_loss=True)


def _cost_record(cfg, shape_name, mesh):
    """flops/bytes/collectives extrapolated from 1- and 2-group unrolled
    compiles (exact for homogeneous stacks; see dryrun docstring)."""
    g_full = cfg.num_layers // len(cfg.layer_pattern)
    recs = []
    for g in (1, 2):
        lowered = _lower_cell(_costing_cfg(cfg, g), shape_name, mesh)
        compiled = lowered.compile()
        cost = _cost_dict(compiled.cost_analysis())
        coll = collective_bytes_from_text(compiled.as_text())
        recs.append((cost, coll))
    (c1, k1), (c2, k2) = recs

    def extra(a, b):
        return {k: a.get(k, 0.0) + (g_full - 1) * (b.get(k, 0.0) - a.get(k, 0.0))
                for k in set(a) | set(b) if not isinstance(a.get(k), dict)}

    cost = extra(c1, c2)
    coll = extra({k: v for k, v in k1.items() if k != "counts"},
                 {k: v for k, v in k2.items() if k != "counts"})
    return {"cost": cost, "collectives": coll, "groups_full": g_full}


def run_cell(arch: str, shape_name: str, multi_pod: bool, mesh=None,
             cfg=None, extra_tag: str = "", save: bool = True,
             costing: bool = True):
    """Lower+compile one cell; returns the result record."""
    t_start = time.time()
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    cfg = cfg if cfg is not None else registry.get_config(arch)
    sc = SHAPES[shape_name]
    # Legacy XLA runs the compressed-DP step fully manual; the models'
    # 'model'-axis sharding constraints are illegal inside that manual
    # context, so leave the active mesh unset there (same degradation as
    # launch.train: model-axis compute replicated per DP shard).
    from repro.dist.compat import HAS_PARTIAL_AUTO
    if (sc.mode != "train" or not getattr(cfg, "grad_compress", False)
            or HAS_PARTIAL_AUTO):
        set_active_mesh(mesh)
    else:
        set_active_mesh(None)

    with mesh:
        lowered = _lower_cell(cfg, shape_name, mesh)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        n_dev = mesh.devices.size
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = collective_bytes_from_text(hlo)
        costing_rec = None
        if costing and not multi_pod:
            try:
                costing_rec = _cost_record(cfg, shape_name, mesh)
            except Exception as e:
                costing_rec = {"error": str(e)[:300]}

    grad_wire = None
    if sc.mode == "train" and getattr(cfg, "grad_compress", False):
        try:
            grad_wire = _grad_wire_model(cfg, mesh)
        except Exception as e:
            grad_wire = {"error": str(e)[:300]}

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": int(n_dev),
        "mode": sc.mode,
        "grad_wire": grad_wire,
        "lower_s": round(t_lower - t_start, 2),
        "compile_s": round(t_compile - t_lower, 2),
        "memory": _mem_dict(mem),
        "cost": _cost_dict(cost),
        "collectives": coll,
        "costing": costing_rec,
        "hlo_bytes": len(hlo),
    }
    print(f"[dryrun] {arch} x {shape_name} x "
          f"{record['mesh']}: compile {record['compile_s']}s, "
          f"flops={record['cost'].get('flops', 0):.3e}, "
          f"coll_bytes={coll.get('total', 0):.3e}", flush=True)
    print("  memory_analysis:", json.dumps(record["memory"]), flush=True)

    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        tag = f"{arch}__{shape_name}__{record['mesh']}{extra_tag}"
        with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def _mem_dict(mem):
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if out:
        n = 512 if jax.device_count() >= 512 else jax.device_count()
        out["per_device_total_gb"] = round(
            (out.get("argument_size_in_bytes", 0)
             + out.get("output_size_in_bytes", 0)
             + out.get("temp_size_in_bytes", 0)
             - out.get("alias_size_in_bytes", 0)) / 1e9, 3)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = runnable_cells(ARCH_IDS)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mp, str(e)[:200]))
    if failures:
        print(f"[dryrun] FAILURES: {len(failures)}")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print("[dryrun] all cells compiled OK")


if __name__ == "__main__":
    main()
