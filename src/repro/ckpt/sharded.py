"""Per-shard checkpoint serialization + restore-with-resharding.

Each process snapshots only its addressable shards (device -> host, the
cheap synchronous half of an async save), serializes them per shard —
optionally through the SZp / TopoSZp pipelines for float32 leaves — and
the manifest records every shard's [start, stop) index so a reader can
reassemble the full leaf on ANY mesh shape.  Restore re-targets the saved
PartitionSpec onto the current mesh (``dist.sharding.adapt_spec``), which
is what lets a checkpoint written on a 4x2 mesh land on a 2x2 one.

Leaf modes (per-mode guarantees, re-verified here on restore):

  * ``raw``     — exact bytes (always used for non-f32 / small leaves)
  * ``szp``     — error-bounded SZp stream, |out - orig| <= eb
  * ``toposzp`` — relaxed-but-strict bound |out - orig| <= 2 eb with the
                  shard's critical points exact: zero false positives,
                  zero false types (checked against the stored label map
                  via ``core.guarantees.violations`` before the leaf is
                  accepted), and CP rank order preserved.

TopoSZp/SZp compress each shard as a 2-D field view: trailing dim kept,
leading dims folded (1-D/scalars become a single row) — the guarantee is
therefore per saved shard, which restore checks shard-by-shard.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import manifest as mf
from repro.core import bitpack, guarantees
from repro.core import io as cio
from repro.core.szp import (SZpParts, szp_compress, szp_compress_batch,
                            szp_decompress, szp_decompress_batch)
from repro.core.toposzp import (TopoSZpCompressed, batch_slice,
                                toposzp_compress, toposzp_compress_batch,
                                toposzp_decompress, toposzp_decompress_batch)
from repro.dist.elastic import mesh_shape_dict
from repro.dist.sharding import adapt_spec, spec_from_json, spec_to_json

DEFAULT_MIN_LOSSY = 4096   # smaller leaves/shards stay raw (header overhead)


def flatten_with_names(tree) -> Tuple[List[str], List[Any], Any]:
    """Stable name-per-leaf flattening shared by save and restore."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class ShardSnap(NamedTuple):
    index: Tuple[Tuple[int, int], ...]   # [start, stop) per dim
    data: Optional[np.ndarray]           # host copy (None when pre-encoded)


class LeafSnap(NamedTuple):
    name: str
    shape: Tuple[int, ...]
    dtype: str
    spec: Optional[list]                 # spec_to_json form, None if unsharded
    shards: List[ShardSnap]
    # Device-side encode (snapshot_tree(mode=lossy)): the shards' raw host
    # copies are skipped and the serialized streams travel instead — the
    # device->host copy happens AFTER compression, on the packed bytes.
    emode: str = "raw"
    blobs: Optional[List[bytes]] = None


def _normalize_index(index, shape) -> Tuple[Tuple[int, int], ...]:
    out = []
    for sl, dim in zip(index, shape):
        start, stop, step = sl.indices(dim)
        if step != 1:
            raise ValueError(f"non-unit shard stride {step}")
        out.append((start, stop))
    return tuple(out)


def snapshot_tree(tree, mode: str = "raw", eb: float = 0.0,
                  backend: Optional[str] = None,
                  min_lossy: int = DEFAULT_MIN_LOSSY,
                  ) -> Tuple[List[LeafSnap], Optional[Dict[str, int]], Any]:
    """Device -> host snapshot of this process's addressable shards.

    Returns (leaf snapshots, mesh {axis: size} or None, treedef).  This is
    the only part of a save that must run synchronously: once the host
    copies exist the step loop may donate/overwrite the device buffers
    while the background writer serializes (double-buffer semantics).

    With a lossy ``mode``, eligible float32 leaves (every shard clearing
    ``min_lossy``, all shards the same shape) are compressed ON DEVICE
    before the copy: the device->host transfer is of the packed stream,
    not the raw leaf, and the snapshot carries the serialized blobs
    (``LeafSnap.blobs``) so the background writer skips its own encode.
    Ineligible leaves fall back to the raw host copy exactly as before.
    """
    names, leaves, treedef = flatten_with_names(tree)
    snaps: List[LeafSnap] = []
    mesh_shape: Optional[Dict[str, int]] = None
    for name, leaf in zip(names, leaves):
        sharding = getattr(leaf, "sharding", None)
        if isinstance(sharding, NamedSharding):
            mesh_shape = mesh_shape_dict(sharding.mesh)
            spec = spec_to_json(sharding.spec)
            dev = [(_normalize_index(s.index, leaf.shape), s.data)
                   for s in leaf.addressable_shards if s.replica_id == 0]
        elif isinstance(leaf, jax.Array):
            spec = None
            dev = [(tuple((0, d) for d in leaf.shape), leaf)]
        else:
            arr = np.asarray(leaf)
            full = tuple((0, d) for d in arr.shape)
            snaps.append(LeafSnap(name, arr.shape, str(arr.dtype), None,
                                  [ShardSnap(full, arr)]))
            continue
        shape, dtype = tuple(leaf.shape), str(leaf.dtype)
        if (mode in mf.LOSSY_MODES and dtype == "float32" and dev
                and all(d.size >= min_lossy for _, d in dev)
                and len({d.shape for _, d in dev}) == 1):
            blobs = encode_shards_device([d for _, d in dev], mode, eb,
                                         backend=backend)
            snaps.append(LeafSnap(name, shape, dtype, spec,
                                  [ShardSnap(idx, None) for idx, _ in dev],
                                  emode=mode, blobs=blobs))
        else:
            snaps.append(LeafSnap(name, shape, dtype, spec,
                                  [ShardSnap(idx, np.asarray(d))
                                   for idx, d in dev]))
    return snaps, mesh_shape, treedef


# --------------------------------------------------------------------------
# Per-shard blob encode / decode
# --------------------------------------------------------------------------

def _field2d(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """2-D field view of a shard: trailing dim kept, leading dims folded."""
    if len(shape) >= 2:
        return int(np.prod(shape[:-1])), int(shape[-1])
    return 1, int(np.prod(shape)) if shape else 1


def leaf_mode(snap: LeafSnap, mode: str,
              min_lossy: int = DEFAULT_MIN_LOSSY) -> str:
    """Effective mode for one leaf: lossy only for float32 leaves whose
    every shard clears the size threshold (tiny blobs stay raw)."""
    if (mode in mf.LOSSY_MODES and snap.dtype == "float32"
            and snap.shards
            and all(s.data.size >= min_lossy for s in snap.shards)):
        return mode
    return "raw"


def encode_shard(data: np.ndarray, mode: str, eb: float,
                 backend: Optional[str] = None) -> bytes:
    if mode == "raw":
        return data.tobytes()
    f2d = jnp.asarray(data.astype(np.float32).reshape(_field2d(data.shape)))
    if mode == "szp":
        return cio.serialize_szp(szp_compress(f2d, eb, backend=backend),
                                 f2d.shape, eb)
    if mode == "toposzp":
        return cio.serialize_toposzp(
            toposzp_compress(f2d, eb, backend=backend), f2d.shape, eb)
    raise ValueError(f"unknown checkpoint mode {mode!r}")


def encode_shards(datas: List[np.ndarray], mode: str, eb: float,
                  backend: Optional[str] = None) -> List[bytes]:
    """Encode all shards of one leaf; same-shape lossy shards are stacked
    through the batched compressors (one compiled call for the whole
    leaf instead of one dispatch per shard).  Byte-identical to
    per-shard :func:`encode_shard` calls."""
    shapes = {d.shape for d in datas}
    if mode == "raw" or len(datas) < 2 or len(shapes) != 1:
        return [encode_shard(d, mode, eb, backend=backend) for d in datas]
    f2d = _field2d(datas[0].shape)
    stack = jnp.asarray(np.stack([d.astype(np.float32).reshape(f2d)
                                  for d in datas]))
    if mode == "szp":
        parts = szp_compress_batch(stack, eb, backend=backend)
        return [cio.serialize_szp(
            jax.tree_util.tree_map(lambda a: a[i], parts), f2d, eb)
            for i in range(len(datas))]
    if mode == "toposzp":
        comp = toposzp_compress_batch(stack, eb, backend=backend)
        return [cio.serialize_toposzp(batch_slice(comp, i), f2d, eb)
                for i in range(len(datas))]
    raise ValueError(f"unknown checkpoint mode {mode!r}")


def encode_shards_device(datas: List[jnp.ndarray], mode: str, eb: float,
                         backend: Optional[str] = None) -> List[bytes]:
    """Batched on-device encode of one leaf's same-shape device shards.

    The compressors run where the data lives; the only device->host
    transfer is ``jax.device_get`` of the packed streams, so the raw leaf
    never crosses the link.  Byte-identical to the host-side
    :func:`encode_shards` path."""
    f2d = _field2d(tuple(datas[0].shape))
    # Shards of a sharded leaf live on different devices; gather them onto
    # one (a device-to-device copy — the bytes still never touch the host)
    # so the batched compressor sees a single stacked array.
    dev0 = next(iter(datas[0].devices()), None)
    stack = jnp.stack([jnp.reshape(jax.device_put(d, dev0).astype(
        jnp.float32), f2d) for d in datas])
    if mode == "szp":
        parts = jax.device_get(szp_compress_batch(stack, eb,
                                                  backend=backend))
        return [cio.serialize_szp(
            jax.tree_util.tree_map(lambda a: a[i], parts), f2d, eb)
            for i in range(len(datas))]
    if mode == "toposzp":
        comp = jax.device_get(toposzp_compress_batch(stack, eb,
                                                     backend=backend))
        return [cio.serialize_toposzp(batch_slice(comp, i), f2d, eb)
                for i in range(len(datas))]
    raise ValueError(f"unknown checkpoint mode {mode!r}")


def decode_shard(blob: bytes, mode: str, dtype: np.dtype,
                 shard_shape: Tuple[int, ...], verify: bool = True,
                 backend: Optional[str] = None) -> np.ndarray:
    if mode == "raw":
        return np.frombuffer(blob, dtype=dtype).reshape(shard_shape).copy()
    if mode == "szp":
        if cio.peek_magic(blob) != cio.MAGIC:
            raise cio.BadStreamError("szp-mode blob has wrong stream magic")
        parts, shape2d, eb, block = cio.deserialize_szp(blob)
        out = szp_decompress(parts, tuple(shape2d), eb, block=block,
                             backend=backend)
        return np.asarray(out).reshape(shard_shape).astype(dtype, copy=False)
    if mode == "toposzp":
        if cio.peek_magic(blob[16:20]) != cio.MAGIC_TOPO:
            raise cio.BadStreamError("toposzp-mode blob has wrong magic")
        comp, shape2d, eb, block = cio.deserialize_toposzp(blob)
        out = toposzp_decompress(comp, tuple(shape2d), eb, block=block,
                                 backend=backend)
        _verify_topo(out, comp, shape2d, verify)
        return np.asarray(out).reshape(shard_shape).astype(dtype, copy=False)
    raise ValueError(f"unknown checkpoint mode {mode!r}")


def _verify_topo(out, comp, shape2d, verify: bool) -> None:
    """Re-verify the topology guarantee against the stored label map: any
    FP/FT here means a corrupt or forged stream."""
    if not verify:
        return
    n = int(shape2d[0]) * int(shape2d[1])
    labels = bitpack.unpack_2bit(comp.labels2b, n).reshape(shape2d)
    if bool(guarantees.violations(out, labels).any()):
        raise IOError("toposzp blob failed the FP/FT guarantee "
                      "re-verification on restore")


def _stack_szp(parsed: List[SZpParts], block: int) -> SZpParts:
    """Stack per-stream SZpParts on a batch axis; payload buffers are
    zero-padded to the widest capacity (harmless: unpack masks every
    magnitude to its block width) and trimmed rank streams to the largest
    block count (zero-width/zero-first padding blocks decode to exactly
    the zeros the CP-first layout guarantees past n_cp)."""
    nb_max = max(int(p.widths.shape[0]) for p in parsed)
    cap = max(int(p.payload.shape[0]) for p in parsed)

    def pad(a, n):
        a = np.asarray(a)
        return np.pad(a, (0, n - a.shape[0]))
    return SZpParts(
        jnp.asarray(np.stack([pad(p.const_bits, -(-nb_max // 8))
                              for p in parsed])),
        jnp.asarray(np.stack([pad(p.widths, nb_max) for p in parsed])),
        jnp.asarray(np.stack([pad(p.signs, -(-nb_max * block // 8))
                              for p in parsed])),
        jnp.asarray(np.stack([pad(p.first, nb_max) for p in parsed])),
        jnp.asarray(np.stack([pad(p.payload, cap) for p in parsed])),
        jnp.asarray(np.stack([np.int32(p.payload_nbytes) for p in parsed])),
        jnp.asarray(np.stack([np.int32(p.nbytes) for p in parsed])))


def decode_shards(blobs: List[bytes], mode: str, dtype: np.dtype,
                  shard_shapes: List[Tuple[int, ...]], verify: bool = True,
                  backend: Optional[str] = None) -> List[np.ndarray]:
    """Decode all shards of one leaf; same-shape lossy streams are stacked
    through the batched decompressors (one compiled call per leaf)."""
    def loop():
        return [decode_shard(b, mode, dtype, s, verify=verify,
                             backend=backend)
                for b, s in zip(blobs, shard_shapes)]
    if (mode not in ("szp", "toposzp") or len(blobs) < 2
            or len(set(shard_shapes)) != 1):
        return loop()
    if mode == "szp":
        if any(cio.peek_magic(b) != cio.MAGIC for b in blobs):
            raise cio.BadStreamError("szp-mode blob has wrong stream magic")
        parsed = [cio.deserialize_szp(b) for b in blobs]
        metas = {(shape2d, eb, block) for _, shape2d, eb, block in parsed}
        if len(metas) != 1:
            return loop()
        (shape2d, eb, block), = metas
        parts = _stack_szp([p for p, _, _, _ in parsed], block)
        outs = szp_decompress_batch(parts, tuple(shape2d), eb, block=block,
                                    backend=backend)
        return [np.asarray(outs[i]).reshape(shard_shapes[i])
                .astype(dtype, copy=False) for i in range(len(blobs))]
    if any(cio.peek_magic(b[16:20]) != cio.MAGIC_TOPO for b in blobs):
        raise cio.BadStreamError("toposzp-mode blob has wrong magic")
    parsed = [cio.deserialize_toposzp(b) for b in blobs]
    metas = {(shape2d, eb, block) for _, shape2d, eb, block in parsed}
    if len(metas) != 1:
        return loop()
    (shape2d, eb, block), = metas
    comps = [c for c, _, _, _ in parsed]
    comp = TopoSZpCompressed(
        _stack_szp([c.szp for c in comps], block),
        jnp.asarray(np.stack([np.asarray(c.labels2b) for c in comps])),
        _stack_szp([c.ranks for c in comps], block),
        jnp.asarray(np.stack([np.int32(c.n_cp) for c in comps])),
        jnp.asarray(np.stack([np.int32(c.nbytes) for c in comps])))
    outs = toposzp_decompress_batch(comp, tuple(shape2d), eb, block=block,
                                    backend=backend)
    for i, c in enumerate(comps):
        _verify_topo(outs[i], c, shape2d, verify)
    return [np.asarray(outs[i]).reshape(shard_shapes[i])
            .astype(dtype, copy=False) for i in range(len(blobs))]


def assemble_leaf(entry: Dict[str, Any], blobs: List[bytes],
                  verify: bool = True,
                  backend: Optional[str] = None) -> np.ndarray:
    """Reassemble a full leaf from its (decoded) shard blobs."""
    shape = tuple(entry["shape"])
    dtype = np.dtype(entry["dtype"])
    full = np.empty(shape, dtype)
    covered = 0
    subs = [tuple(int(b) - int(a) for a, b in sh["index"])
            for sh in entry["shards"]]
    datas = decode_shards(blobs, entry["mode"], dtype, subs, verify=verify,
                          backend=backend)
    for sh, data in zip(entry["shards"], datas):
        full[tuple(slice(int(a), int(b)) for a, b in sh["index"])] = data
        covered += data.size
    if covered != full.size:
        raise IOError(f"shards cover {covered}/{full.size} elements "
                      f"of {entry['name']}")
    return full


def place_leaf(arr: np.ndarray, entry: Dict[str, Any], mesh) -> jnp.ndarray:
    """Lay a reassembled leaf out on ``mesh`` using the SAVED spec adapted
    to the current mesh shape (the resharding half of elastic restore)."""
    if mesh is None:
        return jnp.asarray(arr)
    spec = (spec_from_json(entry["spec"]) if entry.get("spec") is not None
            else P())
    spec = adapt_spec(spec, mesh, arr.shape)
    return jax.device_put(arr, NamedSharding(mesh, spec))
