from repro.ckpt.manager import save, restore, latest_step, prune

__all__ = ["save", "restore", "latest_step", "prune"]
