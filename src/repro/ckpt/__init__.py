from repro.ckpt.coord import BarrierTimeout, CommitTimeout
from repro.ckpt.manager import (CheckpointManager, RestoreResult, latest_step,
                                prune, restore, save)
from repro.ckpt.manifest import LOSSY_MODES, MODES, TreeMismatchError
from repro.ckpt.async_writer import AsyncWriteError, AsyncWriter

__all__ = ["save", "restore", "latest_step", "prune",
           "CheckpointManager", "RestoreResult", "AsyncWriter",
           "AsyncWriteError", "TreeMismatchError", "MODES", "LOSSY_MODES",
           "BarrierTimeout", "CommitTimeout"]
