"""Single-slot background checkpoint writer.

The save path splits into a cheap synchronous half (device -> host shard
snapshot, see ``sharded.snapshot_tree``) and the expensive half (compress,
hash, write, fsync, atomic rename) which runs here on a daemon thread so
``ckpt_every`` no longer stalls the step loop.  One write may be in flight
at a time: submitting the next checkpoint first waits for the previous one
(the only barrier the step loop ever sees — in steady state the previous
write finished during the intervening steps and the wait is free).

Exceptions from the background write are re-raised on the NEXT ``wait()``
/ ``submit()`` so a failing disk surfaces in the step loop rather than
being lost with the thread.  They arrive wrapped in :class:`AsyncWriteError`
carrying the submit label (the checkpoint step) with the original
exception chained as ``__cause__``, and — when obs is enabled — an error
event lands in the registry at failure time, so a failed background save
is attributable from the train-loop's periodic ``[obs]`` lines even
before the next barrier.

Observability (``repro.obs``, all recorded from host timestamps the
writer already has — no device reads): ``ckpt.submit_stall_s`` histogram
(how long ``submit`` blocked on the previous write; ~0 in steady state),
``ckpt.write`` span on the writer thread (its own track in the Chrome
trace), ``ckpt.queue_depth`` gauge (0/1 for the single slot).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

from repro import obs


class AsyncWriteError(RuntimeError):
    """A background checkpoint write failed.

    ``label`` identifies the submission (the manager passes
    ``"step <N>"``); the original exception is chained as ``__cause__``.
    """

    def __init__(self, label: Optional[str], cause: BaseException) -> None:
        where = f" ({label})" if label else ""
        super().__init__(f"background checkpoint write failed{where}: "
                         f"{type(cause).__name__}: {cause}")
        self.label = label
        self.__cause__ = cause


class AsyncWriter:
    """Single-slot background writer with transient-IO retries.

    ``retries``/``backoff_s`` bound how many times a failed write is
    re-attempted when it raises ``OSError``/``IOError`` (a flaky NFS
    mount, a momentarily full disk): each retry waits
    ``min(backoff_s * 2**attempt, backoff_max_s)`` — capped exponential
    backoff — re-runs ``fn`` from scratch (the write paths are
    idempotent: they rebuild their tmp state), and counts
    ``ckpt.write_retries``.  Non-IO failures and exhausted budgets
    surface exactly as before (wrapped as :class:`AsyncWriteError` for
    labeled submissions).  The default ``retries=0`` keeps the writer's
    raw behavior; the checkpoint manager threads its own
    ``write_retries`` knob through.
    """

    def __init__(self, retries: int = 0, backoff_s: float = 0.05,
                 backoff_max_s: float = 2.0) -> None:
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self._thread: Optional[threading.Thread] = None
        self._result: Any = None
        self._exc: Optional[BaseException] = None

    @property
    def in_flight(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def submit(self, fn: Callable[[], Any],
               label: Optional[str] = None) -> None:
        """Run ``fn`` in the background; barriers on the previous write.

        ``label`` tags the submission for error wrapping and the obs
        span (the checkpoint manager passes ``"step <N>"``).
        """
        t0 = time.perf_counter()
        self.wait()
        if obs.enabled():
            obs.observe("ckpt.submit_stall_s", time.perf_counter() - t0)
            obs.counter_add("ckpt.submits", 1)
            obs.gauge_set("ckpt.queue_depth", 1)

        def run() -> None:
            attempt = 0
            try:
                while True:
                    try:
                        with obs.span("ckpt.write", label=label or "",
                                      attempt=attempt):
                            self._result = fn()
                        return
                    except (OSError, IOError) as e:
                        if attempt >= self.retries:
                            self._fail(e, label)
                            return
                        delay = min(self.backoff_s * (2 ** attempt),
                                    self.backoff_max_s)
                        attempt += 1
                        obs.counter_add("ckpt.write_retries", 1)
                        obs.error("ckpt.write_retry",
                                  f"{type(e).__name__}: {e}",
                                  label=label or "", attempt=attempt)
                        time.sleep(delay)
                    except BaseException as e:
                        self._fail(e, label)
                        return
            finally:
                obs.gauge_set("ckpt.queue_depth", 0)

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="ckpt-async-writer")
        self._thread.start()

    def _fail(self, e: BaseException, label: Optional[str]) -> None:
        """Record a terminal failure; re-raised on the next wait()."""
        obs.error("ckpt.write", f"{type(e).__name__}: {e}",
                  label=label or "")
        # labeled submissions (the manager's "step <N>") get the
        # attributable wrapper; bare submissions keep their
        # original exception type
        self._exc = (AsyncWriteError(label, e)
                     if label and not isinstance(e, AsyncWriteError)
                     else e)

    def wait(self) -> Any:
        """Block until the in-flight write (if any) commits; returns its
        result and re-raises its exception."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc
        result, self._result = self._result, None
        return result
