"""Single-slot background checkpoint writer.

The save path splits into a cheap synchronous half (device -> host shard
snapshot, see ``sharded.snapshot_tree``) and the expensive half (compress,
hash, write, fsync, atomic rename) which runs here on a daemon thread so
``ckpt_every`` no longer stalls the step loop.  One write may be in flight
at a time: submitting the next checkpoint first waits for the previous one
(the only barrier the step loop ever sees — in steady state the previous
write finished during the intervening steps and the wait is free).

Exceptions from the background write are re-raised on the NEXT ``wait()``
/ ``submit()`` so a failing disk surfaces in the step loop rather than
being lost with the thread.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional


class AsyncWriter:
    def __init__(self) -> None:
        self._thread: Optional[threading.Thread] = None
        self._result: Any = None
        self._exc: Optional[BaseException] = None

    @property
    def in_flight(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def submit(self, fn: Callable[[], Any]) -> None:
        """Run ``fn`` in the background; barriers on the previous write."""
        self.wait()

        def run() -> None:
            try:
                self._result = fn()
            except BaseException as e:     # re-raised on the next wait()
                self._exc = e

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="ckpt-async-writer")
        self._thread.start()

    def wait(self) -> Any:
        """Block until the in-flight write (if any) commits; returns its
        result and re-raises its exception."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc
        result, self._result = self._result, None
        return result
