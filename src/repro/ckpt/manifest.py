"""Checkpoint manifest v2: the single JSON committing a sharded checkpoint.

A v2 checkpoint directory holds one blob file per writing process plus the
manifest, which is written LAST and acts as the commit marker (a directory
without a readable manifest is an aborted write and is skipped on restore):

    <dir>/step_<N>/
        shards_p0000.bin   — concatenated per-shard blobs of process 0
        shards_p0001.bin   — ... one per process ...
        manifest.json      — v2 manifest (below), the commit record

Manifest schema (``version: 2``)::

    {"version": 2, "step": N,
     "mesh": {"data": 4, "model": 2} | null,     # axis name -> size
     "process_count": 1,
     "leaves": [
       {"name": "params/w", "shape": [256, 64], "dtype": "float32",
        "mode": "raw" | "szp" | "toposzp",
        "eb": 1e-4,                 # ONLY present for lossy modes
        "spec": [["data"], null] | null,         # PartitionSpec per dim
        "shards": [
          {"file": "shards_p0000.bin", "offset": 0, "nbytes": 123,
           "sha256": "...", "index": [[0, 64], [0, 64]]}]}]}

``index`` is the half-open [start, stop) slice of the shard per dim, so a
reader can reassemble the full leaf on ANY mesh (or none) — the basis of
restore-with-resharding.  ``spec`` records the layout intent; restore
re-targets it onto the current mesh via ``dist.sharding.adapt_spec``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

VERSION = 2
MANIFEST = "manifest.json"
LOSSY_MODES = ("szp", "toposzp")
MODES = ("raw",) + LOSSY_MODES


class TreeMismatchError(ValueError):
    """Checkpoint tree structure does not match the restore template.

    Unlike a corrupt blob (skipped with a logged reason, falling back to an
    older checkpoint), a structural mismatch means the CALLER is restoring
    the wrong thing — it propagates instead of silently returning None.
    """


def blob_file(process_index: int) -> str:
    return f"shards_p{process_index:04d}.bin"


def leaf_entry(name: str, shape, dtype: str, mode: str, eb: float,
               spec: Optional[list], shards: List[Dict[str, Any]]
               ) -> Dict[str, Any]:
    if mode not in MODES:
        raise ValueError(f"unknown checkpoint mode {mode!r}")
    entry: Dict[str, Any] = {
        "name": name, "shape": list(shape), "dtype": str(dtype),
        "mode": mode, "spec": spec, "shards": shards,
    }
    if mode in LOSSY_MODES:        # eb is meaningless for exact blobs
        entry["eb"] = eb
    return entry


def build(step: int, leaves: List[Dict[str, Any]],
          mesh_shape: Optional[Dict[str, int]],
          process_count: int = 1) -> Dict[str, Any]:
    return {"version": VERSION, "step": int(step),
            "mesh": mesh_shape, "process_count": int(process_count),
            "leaves": leaves}


def load(path: str) -> Dict[str, Any]:
    """Read + validate a manifest; raises on missing/unreadable/wrong
    version (the restore fallback treats that as an aborted write)."""
    with open(os.path.join(path, MANIFEST)) as f:
        doc = json.load(f)
    if doc.get("version") != VERSION:
        raise IOError(f"unsupported manifest version {doc.get('version')!r} "
                      f"in {path}")
    return doc


def _shard_size(index) -> int:
    n = 1
    for a, b in index:
        n *= int(b) - int(a)
    return n


def check_coverage(doc: Dict[str, Any]) -> None:
    """Validate that every leaf's shards exactly tile its shape.

    A *partial commit* — a crash that published a manifest listing only a
    subset of the writing processes' shards, or a hand-forged shard-subset
    manifest — leaves gaps.  This check runs on manifest metadata alone
    (no blob reads): each shard must sit within bounds, no two shards may
    overlap, and the element counts must sum to the full leaf — together
    that proves an exact tiling.  Raises ``IOError`` so restore treats
    the checkpoint as corrupt and falls back (never half-restores).
    """
    world = int(doc.get("process_count", 1))
    for e in doc["leaves"]:
        shape = [int(d) for d in e["shape"]]
        total = 1
        for d in shape:
            total *= d
        shards = e["shards"]
        if not shards and total:
            raise IOError(f"no shards recorded for {e['name']} "
                          f"(partial commit of a {world}-process save?)")
        covered = 0
        for sh in shards:
            idx = sh["index"]
            if len(idx) != len(shape):
                raise IOError(f"shard rank mismatch for {e['name']}: "
                              f"index {idx} vs shape {shape}")
            for (a, b), dim in zip(idx, shape):
                if not (0 <= int(a) <= int(b) <= dim):
                    raise IOError(f"shard index {idx} out of bounds for "
                                  f"{e['name']} (shape {shape})")
            covered += _shard_size(idx)
        for i in range(len(shards)):
            for j in range(i + 1, len(shards)):
                a, b = shards[i]["index"], shards[j]["index"]
                if _overlap(a, b):
                    raise IOError(f"overlapping shards for {e['name']}: "
                                  f"{a} and {b}")
        if covered != total:
            raise IOError(
                f"shards cover {covered}/{total} elements of {e['name']} "
                f"— partial commit (manifest records process_count="
                f"{world})")


def _overlap(a, b) -> bool:
    """Half-open interval intersection per dim (scalars always collide)."""
    return all(int(x0) < int(y1) and int(y0) < int(x1)
               for (x0, x1), (y0, y1) in zip(a, b))


def check_tree(doc: Dict[str, Any], template_names: List[str]) -> None:
    """Template/treedef agreement: every template leaf must exist in the
    manifest and vice versa — anything else is a structural mismatch."""
    saved = [e["name"] for e in doc["leaves"]]
    if sorted(saved) != sorted(template_names):
        missing = sorted(set(template_names) - set(saved))
        extra = sorted(set(saved) - set(template_names))
        raise TreeMismatchError(
            f"checkpoint tree does not match restore template "
            f"(missing from checkpoint: {missing[:4]}, "
            f"unexpected in checkpoint: {extra[:4]})")
