"""Coordinated multi-host checkpoint commit over a shared filesystem.

Every process of a multi-controller job writes only its addressable
shards (``manifest.blob_file(process_index)``), so committing a
checkpoint needs coordination: a manifest listing only one process's
shards is a *partial commit* — restore would silently produce a
shard-subset state, voiding the zero-FP/FT and 2*eb contracts the
manifest's guarantees re-verification is supposed to re-prove.  This
module is the barrier + single-committer protocol that makes the commit
atomic across processes, using only the shared directory (no RPC):

    step_N.tmp/                      all processes write here concurrently
        shards_p0000.bin             process 0's blob (fsync'd)
        shards_p0001.bin             ...
        ready.0000.json              per-process READY marker, written
        ready.0001.json              atomically AFTER its blob: the
                                     process's manifest fragment (per-leaf
                                     shard docs + blob-file nbytes)
        manifest.json                merged by the COMMITTER, written last
    step_N/                          published by the committer alone via
                                     os.replace (the commit point)

Protocol per process:

  1. write ``shards_p{pid}.bin`` into the shared ``step_N.tmp`` (the dir
     is created ``exist_ok`` — no process may delete it);
  2. publish its READY marker atomically (``.part`` + rename): the
     fragment carries pid/step/world, the blob file's total nbytes, the
     mesh shape, and the per-leaf shard entries (sha256 + [start, stop)
     index) for exactly its shards;
  3. barrier: poll (bounded timeout, exponential backoff) until all
     ``world`` markers exist — :class:`BarrierTimeout` on expiry (a peer
     crashed before its marker: the checkpoint is abandoned, no manifest
     is ever written, restore falls back past the torn directory);
  4. the elected committer — the lowest ready pid — merges the fragments
     into one manifest (validating step/world/mesh agreement, per-leaf
     metadata agreement, and blob-file sizes), writes ``manifest.json``
     LAST, fsyncs, removes the markers, and alone runs the
     ``os.replace`` publish + parent fsync;
  5. non-committers wait for the publish with the same bounded timeout —
     :class:`CommitTimeout` if the committer died pre-manifest (again:
     no commit marker, restore falls back).

The protocol is crash-atomic at every point: the ONLY transition that
makes a checkpoint restorable is the committer's rename of a directory
that already contains a fully merged, fsync'd manifest.  Restore
additionally validates shard *coverage* (``manifest.check_coverage``) so
even a hand-forged subset manifest is detected and fallen past.

Note: saves are assumed to use monotonically increasing steps —
re-committing an already-published step concurrently from two jobs is
not race-protected (the stale directory would satisfy the publish wait).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

from repro import obs
from repro.ckpt import manifest as mf

READY_PREFIX = "ready."
DEFAULT_TIMEOUT_S = 120.0


class BarrierTimeout(TimeoutError):
    """A peer never published its READY marker within the timeout."""


class CommitTimeout(TimeoutError):
    """The committer never published the manifest within the timeout."""


def ready_file(process_index: int) -> str:
    return f"{READY_PREFIX}{process_index:04d}.json"


def committer_index(ready_pids: List[int]) -> int:
    """Single-committer election: the lowest ready process index (with a
    full barrier this is process 0; the function exists so a future
    degraded-commit mode can elect among survivors)."""
    return min(ready_pids)


def write_ready(tmp: str, process_index: int, step: int, world: int,
                fname: str, nbytes: int,
                mesh_shape: Optional[Dict[str, int]],
                entries: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Atomically publish this process's manifest fragment (blob must
    already be durable — the marker asserts 'my shards are on disk').

    The marker is deliberately NOT fsync'd: it is protocol state, not
    durability state.  Durability comes from the blob fsync (already
    done) and the committer's manifest fsync; a marker lost in a machine
    crash just means the barrier times out and the checkpoint is
    correctly abandoned.  The ``.part`` + rename still gives peers
    atomic all-or-nothing visibility."""
    doc = {"pid": int(process_index), "step": int(step),
           "world": int(world), "file": fname, "nbytes": int(nbytes),
           "mesh": mesh_shape, "leaves": entries}
    path = os.path.join(tmp, ready_file(process_index))
    part = path + ".part"
    with open(part, "w") as f:
        json.dump(doc, f)
        f.flush()
    os.replace(part, path)
    return doc


def _poll(predicate: Callable[[], bool], timeout_s: float, exc, what: str,
          poll_s: float = 0.005, backoff: float = 1.6,
          max_poll_s: float = 0.25) -> None:
    """Bounded-timeout wait loop with capped exponential backoff."""
    deadline = time.monotonic() + timeout_s
    delay = poll_s
    while not predicate():
        now = time.monotonic()
        if now >= deadline:
            raise exc(f"{what} (timeout {timeout_s:.1f}s)")
        time.sleep(min(delay, deadline - now))
        delay = min(delay * backoff, max_poll_s)


def _ready_pids(tmp: str) -> List[int]:
    try:
        names = os.listdir(tmp)
    except OSError:
        return []
    out = []
    for n in names:
        if n.startswith(READY_PREFIX) and n.endswith(".json"):
            try:
                out.append(int(n[len(READY_PREFIX):-len(".json")]))
            except ValueError:
                continue
    return sorted(out)


def wait_for_ready(tmp: str, world: int,
                   timeout_s: float = DEFAULT_TIMEOUT_S,
                   final: Optional[str] = None) -> List[int]:
    """Barrier: block until all ``world`` READY markers exist.  Returns
    the sorted pids; records ``ckpt.commit_barrier_s``.

    ``final`` closes a publish race: a fast committer may consume the
    markers and rename ``tmp`` away before a slow peer's poll re-reads
    them — observing the published manifest at ``final`` is then ALSO a
    successful barrier (everyone was ready, by construction)."""
    def committed() -> bool:
        return (final is not None
                and os.path.isfile(os.path.join(final, mf.MANIFEST)))

    t0 = time.perf_counter()
    _poll(lambda: committed() or len(_ready_pids(tmp)) >= world, timeout_s,
          BarrierTimeout, f"waiting for {world} ready markers in {tmp}")
    obs.observe("ckpt.commit_barrier_s", time.perf_counter() - t0)
    pids = _ready_pids(tmp)
    if len(pids) < world and committed():
        return list(range(world))
    if pids != list(range(world)):
        raise IOError(f"ready markers {pids} do not match world {world} "
                      f"(stale markers from another run?)")
    return pids


def wait_for_commit(final: str, timeout_s: float = DEFAULT_TIMEOUT_S
                    ) -> None:
    """Non-committer half of the publish: wait for the committed
    directory (its manifest was written before the rename)."""
    _poll(lambda: os.path.isfile(os.path.join(final, mf.MANIFEST)),
          timeout_s, CommitTimeout,
          f"waiting for the committer to publish {final}")


def load_fragments(tmp: str, step: int, world: int,
                   own: Optional[Dict[str, Any]] = None
                   ) -> List[Dict[str, Any]]:
    """Read + cross-validate all READY fragments (committer side).

    ``own`` is the committer's in-memory fragment (``write_ready``'s
    return value): its slot skips the disk round-trip — it was built
    from the entries just written and its blob is already fsync'd."""
    frags = []
    for pid in range(world):
        if own is not None and own.get("pid") == pid:
            frags.append(own)
            continue
        path = os.path.join(tmp, ready_file(pid))
        with open(path) as f:
            doc = json.load(f)
        if doc.get("pid") != pid:
            raise IOError(f"ready marker {path} claims pid {doc.get('pid')}")
        if doc.get("step") != step or doc.get("world") != world:
            raise IOError(
                f"ready marker {path} is from another commit "
                f"(step {doc.get('step')} world {doc.get('world')}, "
                f"expected step {step} world {world})")
        blob = os.path.join(tmp, doc["file"])
        got = os.path.getsize(blob) if os.path.isfile(blob) else -1
        if got != doc["nbytes"]:
            raise IOError(f"blob {blob} has {got} bytes, marker promised "
                          f"{doc['nbytes']} (torn write?)")
        frags.append(doc)
    return frags


_LEAF_META = ("shape", "dtype", "mode", "spec")


def merge_fragments(frags: List[Dict[str, Any]], step: int, world: int
                    ) -> Dict[str, Any]:
    """Merge per-process fragments into the single v2 manifest doc.

    Leaves are keyed by name (order taken from the first fragment that
    mentions each — all processes flatten the same tree, so that is the
    shared flatten order); per-leaf metadata must agree across fragments
    and shard docs are concatenated in pid order.  A process that holds
    no addressable shard of a leaf contributes an empty ``shards`` list.
    """
    meshes = [f["mesh"] for f in frags if f.get("mesh") is not None]
    if meshes and any(m != meshes[0] for m in meshes):
        raise IOError(f"fragments disagree on the mesh: {meshes}")
    merged: Dict[str, Dict[str, Any]] = {}
    order: List[str] = []
    for frag in frags:
        for e in frag["leaves"]:
            name = e["name"]
            if name not in merged:
                entry = dict(e)
                entry["shards"] = list(e["shards"])
                merged[name] = entry
                order.append(name)
                continue
            have = merged[name]
            for k in _LEAF_META:
                if have.get(k) != e.get(k):
                    raise IOError(
                        f"fragments disagree on {name}.{k}: "
                        f"{have.get(k)!r} vs {e.get(k)!r} "
                        f"(pid {frag['pid']})")
            if have.get("eb") != e.get("eb"):
                raise IOError(f"fragments disagree on {name}.eb")
            have["shards"].extend(e["shards"])
    leaves = [merged[n] for n in order]
    return mf.build(step, leaves, meshes[0] if meshes else None, world)
