"""Fault-tolerant checkpoint manager with optional SZp compression.

Layout per checkpoint:  <dir>/step_<N>/
    manifest.json   — tree structure, shapes, dtypes, per-blob sha256, mode
    data.bin        — concatenated per-leaf blobs

Writes are atomic (tmp dir + os.replace) and verified by content hash on
restore; a corrupt/partial checkpoint is skipped and the previous one is
used — the restart path the training loop exercises (tests simulate a
mid-run preemption).

Modes per-leaf:
  * 'raw'  — exact bytes (default for ints / small tensors / exact restart)
  * 'szp'  — error-bounded SZp stream for float arrays (space saver for
             non-critical state; error bound recorded in the manifest)
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import io as cio
from repro.core.szp import szp_compress, szp_decompress

_MANIFEST = "manifest.json"
_DATA = "data.bin"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(tree, step: int, directory: str, compress: Optional[str] = None,
         eb: float = 1e-4) -> str:
    """Write an atomic checkpoint; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _flatten_with_names(tree)
    entries = []
    blobs = []
    offset = 0
    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        mode = "raw"
        if (compress == "szp" and arr.dtype in (np.float32,)
                and arr.size >= 4096):
            parts = szp_compress(jnp.asarray(arr).reshape(-1), eb)
            blob = cio.serialize_szp(parts, (1, arr.size), eb)
            mode = "szp"
        else:
            blob = arr.tobytes()
        blobs.append(blob)
        entries.append({
            "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "mode": mode, "offset": offset, "nbytes": len(blob),
            "sha256": hashlib.sha256(blob).hexdigest(), "eb": eb,
        })
        offset += len(blob)

    with open(os.path.join(tmp, _DATA), "wb") as f:
        for b in blobs:
            f.write(b)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump({"step": step, "entries": entries}, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _load_one(path: str, tree_template) -> Tuple[Any, int]:
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = open(os.path.join(path, _DATA), "rb").read()
    names, leaves, treedef = _flatten_with_names(tree_template)
    by_name = {e["name"]: e for e in manifest["entries"]}
    out = []
    for name, leaf in zip(names, leaves):
        e = by_name[name]
        blob = data[e["offset"]: e["offset"] + e["nbytes"]]
        if hashlib.sha256(blob).hexdigest() != e["sha256"]:
            raise IOError(f"checkpoint blob hash mismatch for {name}")
        if e["mode"] == "szp":
            parts, shape, eb, block = cio.deserialize_szp(blob)
            arr = np.asarray(szp_decompress(parts, (1, shape[1]), eb,
                                            block=block)).reshape(e["shape"])
            arr = arr.astype(e["dtype"])
        else:
            arr = np.frombuffer(blob, dtype=np.dtype(e["dtype"])).reshape(
                e["shape"]).copy()
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return steps[-1] if steps else None


def restore(directory: str, tree_template) -> Optional[Tuple[Any, int]]:
    """Load the newest valid checkpoint (falling back past corrupt ones)."""
    if not os.path.isdir(directory):
        return None
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(directory)
                    if d.startswith("step_") and not d.endswith(".tmp")),
                   reverse=True)
    for s in steps:
        path = os.path.join(directory, f"step_{s:08d}")
        try:
            return _load_one(path, tree_template)
        except Exception:   # corrupt / partial: try the previous one
            continue
    return None


def prune(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
