"""Fault-tolerant checkpoint manager: v1 single-blob and v2 sharded layouts.

v1 layout (``save``/``restore``, kept for single-host exact restarts and
backward compatibility):  <dir>/step_<N>/{manifest.json, data.bin}.

v2 layout (``CheckpointManager``): per-shard blobs + a v2 manifest (see
``ckpt.manifest``) — each process serializes only its addressable shards,
float32 leaves may ride the SZp/TopoSZp streams (``mode``), writes run on
a background thread (``ckpt.async_writer``), and restore reassembles the
shards onto ANY mesh shape (restore-with-resharding, the elastic restart
path of ``train.loop``).

Both layouts write atomically: blobs + manifest land in ``step_N.tmp``
(files fsync'd, then the tmp directory), ``os.replace`` publishes the
directory, and the PARENT directory is fsync'd so the rename itself is
durable across a crash.  Restore verifies per-blob content hashes; a
corrupt/partial checkpoint is skipped WITH A LOGGED REASON and the
previous one is used, while a structural (template/treedef) mismatch
raises ``TreeMismatchError`` instead of silently training from scratch.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import shutil
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, obs
from repro.ckpt import coord
from repro.ckpt import manifest as mf
from repro.ckpt import sharded
from repro.ckpt.async_writer import AsyncWriter
from repro.ckpt.manifest import TreeMismatchError
from repro.ckpt.sharded import flatten_with_names as _flatten_with_names
from repro.core import io as cio
from repro.core.szp import szp_compress, szp_decompress

_MANIFEST = "manifest.json"
_DATA = "data.bin"

Log = Optional[Callable[[str], None]]


def _fsync_dir(path: str) -> None:
    """fsync a directory so its entries (a just-renamed checkpoint, new
    blob files) survive a crash; no-op where dirs can't be opened."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _step_dirs(directory: str, reverse: bool = False) -> List[int]:
    if not os.path.isdir(directory):
        return []
    return sorted((int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp")),
                  reverse=reverse)


# --------------------------------------------------------------------------
# v1: single data.bin per checkpoint (single-host)
# --------------------------------------------------------------------------

def save(tree, step: int, directory: str, compress: Optional[str] = None,
         eb: float = 1e-4) -> str:
    """Write an atomic v1 checkpoint; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _flatten_with_names(tree)
    entries = []
    blobs = []
    offset = 0
    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        mode = "raw"
        if (compress == "szp" and arr.dtype in (np.float32,)
                and arr.size >= 4096):
            parts = szp_compress(jnp.asarray(arr).reshape(-1), eb)
            blob = cio.serialize_szp(parts, (1, arr.size), eb)
            mode = "szp"
        else:
            blob = arr.tobytes()
        blobs.append(blob)
        entry = {
            "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "mode": mode, "offset": offset, "nbytes": len(blob),
            "sha256": hashlib.sha256(blob).hexdigest(),
        }
        if mode in mf.LOSSY_MODES:   # eb is meaningless on exact blobs
            entry["eb"] = eb
        entries.append(entry)
        offset += len(blob)

    with open(os.path.join(tmp, _DATA), "wb") as f:
        for b in blobs:
            f.write(b)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump({"step": step, "entries": entries}, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(directory)   # make the rename itself durable
    return final


def _load_one(path: str, tree_template) -> Tuple[Any, int]:
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = open(os.path.join(path, _DATA), "rb").read()
    names, leaves, treedef = _flatten_with_names(tree_template)
    by_name = {e["name"]: e for e in manifest["entries"]}
    if sorted(by_name) != sorted(names):
        missing = sorted(set(names) - set(by_name))
        extra = sorted(set(by_name) - set(names))
        raise TreeMismatchError(
            f"checkpoint tree does not match restore template "
            f"(missing from checkpoint: {missing[:4]}, "
            f"unexpected in checkpoint: {extra[:4]})")
    out = []
    for name, leaf in zip(names, leaves):
        e = by_name[name]
        tpl_dtype = getattr(leaf, "dtype", None)
        if tpl_dtype is not None and str(tpl_dtype) != e["dtype"]:
            raise IOError(f"dtype drift for {name}: checkpoint has "
                          f"{e['dtype']}, template expects {tpl_dtype}")
        tpl_shape = getattr(leaf, "shape", None)
        if tpl_shape is not None and tuple(tpl_shape) != tuple(e["shape"]):
            raise TreeMismatchError(
                f"shape mismatch for {name}: checkpoint has {e['shape']}, "
                f"template expects {tuple(tpl_shape)}")
        blob = data[e["offset"]: e["offset"] + e["nbytes"]]
        if hashlib.sha256(blob).hexdigest() != e["sha256"]:
            raise IOError(f"checkpoint blob hash mismatch for {name}")
        if e["mode"] == "szp":
            parts, shape, eb, block = cio.deserialize_szp(blob)
            arr = np.asarray(szp_decompress(parts, (1, shape[1]), eb,
                                            block=block)).reshape(e["shape"])
            arr = arr.astype(e["dtype"])
        else:
            arr = np.frombuffer(blob, dtype=np.dtype(e["dtype"])).reshape(
                e["shape"]).copy()
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


def latest_step(directory: str) -> Optional[int]:
    steps = _step_dirs(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree_template,
            log: Log = None) -> Optional[Tuple[Any, int]]:
    """Load the newest valid v1 checkpoint (falling back past corrupt ones,
    each skip logged with its reason; structural mismatches re-raise)."""
    for s in _step_dirs(directory, reverse=True):
        path = os.path.join(directory, f"step_{s:08d}")
        try:
            return _load_one(path, tree_template)
        except TreeMismatchError:
            raise                   # wrong template: never silently skip
        except Exception as e:      # corrupt / partial: try the previous one
            if log is not None:
                log(f"[ckpt] skipping step {s}: "
                    f"{type(e).__name__}: {e}")
            continue
    return None


def prune(directory: str, keep: int = 3, skip=()) -> None:
    """Delete all but the newest ``keep`` checkpoints.

    ``skip`` lists steps that must survive regardless of age — the step
    an async writer currently holds (snapshot taken, commit pending or
    just published): pruning it would race the writer's ``os.replace``
    and delete a checkpoint the step loop believes exists.  ``.tmp``
    in-flight directories are never candidates (``_step_dirs`` excludes
    them), so a concurrent uncommitted write is untouchable by design.
    """
    skip = set(skip)
    for s in _step_dirs(directory)[:-keep] if keep else []:
        if s in skip:
            continue
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


# --------------------------------------------------------------------------
# v2: sharded + async + resharding-aware
# --------------------------------------------------------------------------

def _write_blobs(tmp: str, step: int, snaps: List[sharded.LeafSnap],
                 mode: str, eb: float, min_lossy: int,
                 backend: Optional[str], process_index: int
                 ) -> Tuple[str, List[Dict[str, Any]], int]:
    """Write this process's blob file; returns (fname, leaf entries with
    ONLY its shard docs, total bytes).  Shared by the single-controller
    and coordinated commit paths.

    Fault sites: ``ckpt.write`` fires before any byte lands (the
    transient-IO fault the async writer's retry loop absorbs);
    ``ckpt.blob`` may tear each blob on its way to disk (the manifest
    keeps the hash of the INTENDED bytes — exactly a torn write)."""
    faults.fire("ckpt.write", step=step, pid=process_index)
    fname = mf.blob_file(process_index)
    entries = []
    offset = 0
    with obs.span("ckpt.write_blobs", step=step, leaves=len(snaps)), \
            open(os.path.join(tmp, fname), "wb") as f:
        for snap in snaps:
            try:
                shard_docs = []
                if snap.blobs is not None:   # encoded on device at snapshot
                    emode, blobs = snap.emode, snap.blobs
                else:
                    emode = sharded.leaf_mode(snap, mode, min_lossy)
                    blobs = sharded.encode_shards(
                        [sh.data for sh in snap.shards], emode, eb,
                        backend=backend)
                for sh, blob in zip(snap.shards, blobs):
                    f.write(faults.mangle("ckpt.blob", blob, step=step,
                                          leaf=snap.name))
                    shard_docs.append({
                        "file": fname, "offset": offset,
                        "nbytes": len(blob),
                        "sha256": hashlib.sha256(blob).hexdigest(),
                        "index": [[a, b] for a, b in sh.index],
                    })
                    offset += len(blob)
                entries.append(mf.leaf_entry(snap.name, snap.shape,
                                             snap.dtype, emode, eb,
                                             snap.spec, shard_docs))
            except Exception as e:
                raise RuntimeError(
                    f"checkpoint write failed at step {step}, leaf "
                    f"{snap.name!r}: {type(e).__name__}: {e}") from e
        f.flush()
        os.fsync(f.fileno())
    return fname, entries, offset


def _publish(tmp: str, final: str, directory: str, doc: Dict[str, Any],
             step: int, offset: int,
             pre_rename: Optional[Callable[[], None]] = None) -> None:
    """Write the manifest LAST, fsync, and atomically publish the
    directory — the single transition that makes the checkpoint real.

    ``pre_rename`` runs after the manifest is durable but before the
    rename (the coordinated path removes its READY markers there: they
    must survive until the manifest exists — a committer dying earlier
    would otherwise strand peers still polling the barrier — but must
    not leak into the published directory)."""
    with obs.span("ckpt.commit", step=step, blob_bytes=offset):
        faults.fire("ckpt.before_manifest", step=step)
        with open(os.path.join(tmp, mf.MANIFEST), "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if pre_rename is not None:
            pre_rename()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _fsync_dir(directory)
    obs.counter_add("ckpt.commits", 1)
    obs.counter_add("ckpt.blob_bytes", float(offset))


def _write_v2(directory: str, step: int, snaps: List[sharded.LeafSnap],
              mesh_shape: Optional[Dict[str, int]], mode: str, eb: float,
              min_lossy: int, keep: Optional[int], log: Log,
              backend: Optional[str] = None) -> str:
    """Serialize a snapshot to an atomic v2 checkpoint (single-controller
    background half)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    _, entries, offset = _write_blobs(tmp, step, snaps, mode, eb,
                                      min_lossy, backend,
                                      jax.process_index())
    doc = mf.build(step, entries, mesh_shape, 1)
    _publish(tmp, final, directory, doc, step, offset)
    if keep is not None:
        prune(directory, keep, skip={step})
    if log is not None:
        log(f"[ckpt] committed {final} ({offset} blob bytes, mode={mode})")
    return final


def _write_v2_coord(directory: str, step: int,
                    snaps: List[sharded.LeafSnap],
                    mesh_shape: Optional[Dict[str, int]], mode: str,
                    eb: float, min_lossy: int, keep: Optional[int],
                    log: Log, backend: Optional[str],
                    process_index: int, process_count: int,
                    timeout_s: float) -> str:
    """Coordinated multi-process commit (see ``ckpt.coord``): every
    process writes its own blob + READY marker into the SHARED tmp dir;
    the elected committer merges the fragments and alone publishes."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    # The tmp dir is shared: create exist_ok and clear only OWN stale
    # files from an aborted previous attempt of this step.
    os.makedirs(tmp, exist_ok=True)
    for stale in (mf.blob_file(process_index),
                  coord.ready_file(process_index)):
        try:
            os.remove(os.path.join(tmp, stale))
        except OSError:
            pass

    fname, entries, offset = _write_blobs(tmp, step, snaps, mode, eb,
                                          min_lossy, backend,
                                          process_index)
    faults.fire("ckpt.before_barrier", step=step, pid=process_index)
    own = coord.write_ready(tmp, process_index, step, process_count, fname,
                            offset, mesh_shape, entries)
    pids = coord.wait_for_ready(tmp, process_count, timeout_s, final=final)

    if process_index == coord.committer_index(pids):
        frags = coord.load_fragments(tmp, step, process_count, own=own)
        doc = coord.merge_fragments(frags, step, process_count)
        mf.check_coverage(doc)     # refuse to publish a torn merge

        def _drop_markers():
            # only once the manifest is durable: removing them earlier
            # would strand a peer still polling the barrier if the
            # committer dies pre-manifest (BarrierTimeout instead of
            # the correct CommitTimeout abandonment)
            for pid in pids:
                try:
                    os.remove(os.path.join(tmp, coord.ready_file(pid)))
                except OSError:
                    pass

        _publish(tmp, final, directory, doc, step, offset,
                 pre_rename=_drop_markers)
        if keep is not None:
            prune(directory, keep, skip={step})
        if log is not None:
            log(f"[ckpt] committed {final} (committer p{process_index}, "
                f"{process_count} processes, mode={mode})")
    else:
        coord.wait_for_commit(final, timeout_s)
        if log is not None:
            log(f"[ckpt] p{process_index} observed commit of {final}")
    return final


def _load_v2(path: str, template, mesh, verify: bool,
             backend: Optional[str] = None) -> Tuple[Any, int,
                                                     Optional[dict]]:
    doc = mf.load(path)
    names, leaves, treedef = _flatten_with_names(template)
    mf.check_tree(doc, names)
    # Shard-coverage validation: a partial commit (manifest listing only
    # a subset of the writing processes' shards) is detected from the
    # metadata alone and treated as corrupt — never half-restored.
    mf.check_coverage(doc)
    by_name = {e["name"]: e for e in doc["leaves"]}
    files: Dict[str, bytes] = {}
    out = []
    for name, leaf in zip(names, leaves):
        e = by_name[name]
        tpl_dtype = getattr(leaf, "dtype", None)
        if tpl_dtype is not None and str(tpl_dtype) != e["dtype"]:
            raise IOError(f"dtype drift for {name}: checkpoint has "
                          f"{e['dtype']}, template expects {tpl_dtype}")
        tpl_shape = getattr(leaf, "shape", None)
        if tpl_shape is not None and tuple(tpl_shape) != tuple(e["shape"]):
            raise TreeMismatchError(
                f"shape mismatch for {name}: checkpoint has {e['shape']}, "
                f"template expects {tuple(tpl_shape)}")
        blobs = []
        for sh in e["shards"]:
            if sh["file"] not in files:
                files[sh["file"]] = open(os.path.join(path, sh["file"]),
                                         "rb").read()
            blob = files[sh["file"]][sh["offset"]: sh["offset"] + sh["nbytes"]]
            if hashlib.sha256(blob).hexdigest() != sh["sha256"]:
                raise IOError(f"blob hash mismatch for {name} "
                              f"shard {sh['index']}")
            blobs.append(blob)
        full = sharded.assemble_leaf(e, blobs, verify=verify,
                                     backend=backend)
        out.append(sharded.place_leaf(full, e, mesh))
    return (jax.tree_util.tree_unflatten(treedef, out), doc["step"],
            doc.get("mesh"))


class RestoreResult(NamedTuple):
    tree: Any
    step: int
    saved_mesh: Optional[Dict[str, int]]   # mesh the checkpoint was saved on


class CheckpointManager:
    """v2 checkpointing: sharded blobs, lossy leaf modes, async writes,
    restore-with-resharding.

    Args:
      directory:  checkpoint root (one ``step_N`` dir per checkpoint).
      mode:       'raw' | 'szp' | 'toposzp' leaf mode for large f32 leaves.
      eb:         absolute error bound for the lossy modes.
      async_write: serialize+fsync on a background thread; the step loop
        only pays for the device->host snapshot (barrier if the previous
        write is still in flight).
      keep:       checkpoints retained after each save (None = all).
      min_compress_size: f32 leaves/shards below this stay raw.
      verify_restore: re-check hashes and the TopoSZp FP/FT guarantee.
      write_retries / write_backoff_s: transient ``OSError`` retry budget
        of the background writer (capped exponential backoff) before the
        failure surfaces as ``AsyncWriteError``.
      process_index / process_count: multi-controller identity; default
        to ``jax.process_index()`` / ``jax.process_count()``.  Override
        for non-JAX launchers or protocol tests.
      coordinated: force the coordinated commit protocol (None = only
        when ``process_count > 1``).  With multiple processes, every
        process writes its own blob + READY marker into the shared tmp
        dir and the elected committer merges + publishes (``ckpt.coord``).
      barrier_timeout_s: bounded wait for peers' READY markers and for
        the committer's publish.
    """

    def __init__(self, directory: str, mode: str = "raw", eb: float = 1e-4,
                 async_write: bool = True, keep: Optional[int] = 3,
                 min_compress_size: int = sharded.DEFAULT_MIN_LOSSY,
                 verify_restore: bool = True, log: Log = print,
                 kernel_backend: Optional[str] = None,
                 write_retries: int = 2, write_backoff_s: float = 0.05,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None,
                 coordinated: Optional[bool] = None,
                 barrier_timeout_s: float = coord.DEFAULT_TIMEOUT_S):
        if mode not in mf.MODES:
            raise ValueError(f"mode must be one of {mf.MODES}, got {mode!r}")
        self.directory = directory
        self.mode = mode
        self.eb = float(eb)
        self.async_write = async_write
        self.keep = keep
        self.min_compress_size = min_compress_size
        self.verify_restore = verify_restore
        self.log = log
        # TopoSZp/SZp kernel dispatch for blob encode/decode (None/"auto"
        # resolves to the hardware default, see kernels.ops.resolve_backend)
        self.kernel_backend = kernel_backend
        self._pid = process_index
        self._world = process_count
        self.coordinated = coordinated
        self.barrier_timeout_s = float(barrier_timeout_s)
        self._writer = AsyncWriter(retries=write_retries,
                                   backoff_s=write_backoff_s)
        # Commit ledger: which submitted steps actually landed / failed —
        # the train loop reconciles report.checkpoints against this so a
        # failed background write never leaves a phantom checkpoint.
        self._committed: List[int] = []
        self._failed: List[Tuple[int, str]] = []
        self._held_step: Optional[int] = None

    @property
    def in_flight(self) -> bool:
        return self._writer.in_flight

    @property
    def committed_steps(self) -> List[int]:
        """Steps whose write COMMITTED through this manager (in order)."""
        return list(self._committed)

    @property
    def failed_steps(self) -> List[Tuple[int, str]]:
        """(step, reason) for every write that failed through this
        manager — the source of ``LoopReport.failed_checkpoints``."""
        return list(self._failed)

    @property
    def held_step(self) -> Optional[int]:
        """The step the writer currently holds (snapshot taken, commit
        pending) — retention jobs must never prune it."""
        return self._held_step

    def _resolve_world(self) -> Tuple[int, int]:
        pid = self._pid if self._pid is not None else jax.process_index()
        world = (self._world if self._world is not None
                 else jax.process_count())
        return pid, world

    def save(self, tree, step: int) -> Optional[str]:
        """Checkpoint ``tree``.  Synchronous mode returns the committed
        path; async mode snapshots device->host, hands the write to the
        background thread and returns None (``wait()`` for the path).

        With ``process_count > 1`` every process must call ``save`` with
        the same step: the write runs the coordinated commit protocol
        (per-process blobs, filesystem barrier, single elected committer
        publishing the merged manifest last — see ``ckpt.coord``)."""
        pid, world = self._resolve_world()
        coordinated = (self.coordinated if self.coordinated is not None
                       else world > 1)
        with obs.span("ckpt.save", step=step, mode=self.mode):
            with obs.span("ckpt.snapshot", step=step):
                snaps, mesh_shape, _ = sharded.snapshot_tree(
                    tree, mode=self.mode, eb=self.eb,
                    backend=self.kernel_backend,
                    min_lossy=self.min_compress_size)
            if coordinated:
                write = functools.partial(
                    _write_v2_coord, self.directory, step, snaps,
                    mesh_shape, self.mode, self.eb,
                    self.min_compress_size,
                    self.keep if pid == 0 else None, self.log,
                    self.kernel_backend, pid, world,
                    self.barrier_timeout_s)
            else:
                write = functools.partial(
                    _write_v2, self.directory, step, snaps, mesh_shape,
                    self.mode, self.eb, self.min_compress_size, self.keep,
                    self.log, backend=self.kernel_backend)
            fn = functools.partial(self._record_outcome, write, step)
            if self.async_write:
                # barriers on the previous write only
                self._held_step = step
                self._writer.submit(fn, label=f"step {step}")
                return None
            self._held_step = step
            return fn()

    def _record_outcome(self, write: Callable[[], str], step: int) -> str:
        """Run the write and keep the commit ledger honest."""
        try:
            path = write()
        except BaseException as e:
            self._failed.append((step, f"{type(e).__name__}: {e}"))
            if self._held_step == step:
                self._held_step = None
            raise
        self._committed.append(step)
        # a transient failure absorbed by the writer's retry is not a
        # failure: the commit supersedes the earlier attempts' records
        self._failed = [(s, r) for s, r in self._failed if s != step]
        if self._held_step == step:
            self._held_step = None
        return path

    def wait(self) -> Optional[str]:
        """Barrier: block until the in-flight write (if any) commits."""
        return self._writer.wait()

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def peek_mesh(self) -> Optional[Dict[str, int]]:
        """Mesh shape recorded by the newest readable manifest (or None)
        — what the elastic restart path compares against the live mesh."""
        for s in _step_dirs(self.directory, reverse=True):
            try:
                return mf.load(
                    os.path.join(self.directory, f"step_{s:08d}")).get("mesh")
            except Exception:
                continue
        return None

    def restore(self, template, mesh=None) -> Optional[RestoreResult]:
        """Load the newest valid checkpoint, reassembling shards and laying
        leaves out on ``mesh`` (saved specs adapted to its shape).  Falls
        back past corrupt/partial checkpoints with a logged reason;
        re-raises structural template mismatches."""
        self.wait()   # never read the directory under an in-flight write
        for s in _step_dirs(self.directory, reverse=True):
            path = os.path.join(self.directory, f"step_{s:08d}")
            try:
                tree, step, saved_mesh = _load_v2(path, template, mesh,
                                                  self.verify_restore,
                                                  backend=self.kernel_backend)
                return RestoreResult(tree, step, saved_mesh)
            except TreeMismatchError:
                raise
            except Exception as e:
                if self.log is not None:
                    self.log(f"[ckpt] skipping step {s}: "
                             f"{type(e).__name__}: {e}")
                continue
        return None
