"""AdamW with fp32 master weights + moments (bf16 model params)."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    master: object     # fp32 param copies
    m: object
    v: object


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    """Returns (init, update); update(grads, state, params) -> (params', state')."""

    def init(params) -> AdamWState:
        f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
        zeros = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        return AdamWState(jnp.zeros((), jnp.int32), f32(params),
                          zeros(params), zeros(params))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm is not None:
            gn = global_norm(g32)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        lr = lr_fn(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, g32)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                         state.v, g32)

        def upd(master, mm, vv):
            mhat = mm / b1c
            vhat = vv / b2c
            return master - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                  + weight_decay * master)

        master = jax.tree.map(upd, state.master, m, v)
        new_params = jax.tree.map(
            lambda mst, p: mst.astype(p.dtype), master, params)
        return new_params, AdamWState(step, master, m, v)

    return Optimizer(init, update)
