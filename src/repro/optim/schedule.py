"""LR schedules: WSD (MiniCPM's warmup-stable-decay), cosine, linear."""
from __future__ import annotations

import jax.numpy as jnp


def wsd(peak_lr: float, warmup: int, stable: int, decay: int,
        floor_frac: float = 0.1):
    """Warmup-Stable-Decay [arXiv:2404.06395]."""

    def fn(step):
        s = step.astype(jnp.float32)
        wu = peak_lr * s / max(warmup, 1)
        dec_t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak_lr * (1.0 - (1.0 - floor_frac) * dec_t)
        return jnp.where(s < warmup, wu, jnp.where(s < warmup + stable,
                                                   peak_lr, dec))

    return fn


def cosine(peak_lr: float, warmup: int, total: int,
           floor_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        wu = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, wu, peak_lr * cos)

    return fn


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
