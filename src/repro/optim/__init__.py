from repro.optim.adamw import adamw, AdamWState, Optimizer, global_norm
from repro.optim.schedule import wsd, cosine, constant

__all__ = ["adamw", "AdamWState", "Optimizer", "global_norm", "wsd",
           "cosine", "constant"]
