"""Distributed layer: sharding rules, compressed collectives, elasticity.

``dist.sharding``    — NamedSharding rules for params / batches / caches
``dist.collectives`` — error-bounded compressed gradient psum (+EF),
                       topo-aware variant with an exact top-|g| sidecar
``dist.ring``        — bitpacked ppermute ring all-reduce (the "packed"
                       wire format: actual compressed bytes on the wire)
``dist.elastic``     — largest-valid-mesh rebuild after device loss
``dist.compat``      — shard_map shim across JAX versions
"""
from repro.dist import collectives, compat, elastic, ring, sharding
from repro.dist.collectives import (WIRE_FORMATS, code_bits,
                                    compressed_psum_tree, max_code,
                                    protect_k, quantize_dequantize_sum,
                                    sidecar_bits, topk_rank_preservation,
                                    topo_compressed_psum_tree,
                                    topo_quantize_dequantize_sum,
                                    topo_wire_bits)
from repro.dist.compat import shard_map
from repro.dist.elastic import (DeviceLoss, largest_mesh_shape,
                                mesh_shape_dict, rebuild_mesh)
from repro.dist.ring import (packed_psum_tree, packed_wire_summary,
                             simulate_hop_bytes)
from repro.dist.sharding import (adapt_spec, batch_axes, cache_shardings,
                                 data_sharding, param_shardings, replicated,
                                 spec_from_json, spec_to_json)

__all__ = [
    "collectives", "compat", "elastic", "ring", "sharding",
    "WIRE_FORMATS", "code_bits", "compressed_psum_tree", "max_code",
    "quantize_dequantize_sum",
    "protect_k", "sidecar_bits", "topk_rank_preservation",
    "topo_compressed_psum_tree", "topo_quantize_dequantize_sum",
    "topo_wire_bits",
    "packed_psum_tree", "packed_wire_summary", "simulate_hop_bytes",
    "shard_map", "DeviceLoss", "largest_mesh_shape", "mesh_shape_dict",
    "rebuild_mesh",
    "adapt_spec", "batch_axes", "cache_shardings", "data_sharding",
    "param_shardings", "replicated", "spec_from_json", "spec_to_json",
]
