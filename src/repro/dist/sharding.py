"""NamedSharding rules for every model family (dense / MoE / ssm / hybrid).

Parameters follow megatron column/row parallelism on the 'model' axis:
fused attention projections shard their feature dim, FFN in/gate shard the
hidden dim, out-projections shard their input dim, the embedding and LM
head shard the (256-padded) vocab, and MoE expert stacks shard the expert
dim (EP).  Under the fsdp strategy the remaining large dim additionally
shards over the data axes (fully sharded params/optimizer; tiny tensors
stay replicated).  Every rule is divisibility-guarded: an axis that does
not divide the dim is dropped rather than padded.

Activations/batches shard their leading dim over the pod-aware data axes
('pod','data' on the multi-pod mesh), or over EVERY axis under fsdp
(pure-DP activations); decode caches shard batch over data and the kv-head
dim over 'model' when it divides.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# leaf names whose LAST dim is the sharded feature dim (column parallel)
_COL = {
    "w_q", "w_k", "w_v",              # attention fused projections
    "w_in", "w_gate",                 # dense FFN (2-D)
    "w_branch", "w_gate_branch",      # RG-LRU input branches
    "w_a", "w_x",                     # RG-LRU recurrence gates
    "w_r", "w_g",                     # RWKV time-mix projections
    "c_wk", "c_wr",                   # RWKV channel-mix
    "wa",                             # RWKV decay LoRA (down)
    "lora_a",                         # RWKV ddlerp LoRA (down)
}
# leaf names whose FIRST (of the trailing two) dims is sharded (row parallel)
_ROW = {"w_o", "w_out", "c_wv", "wb"}


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the data-parallel batch dim (pod-aware)."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def replicated(x: Any, mesh) -> Any:
    """Fully-replicated NamedSharding(s) matching the structure of ``x``."""
    rep = NamedSharding(mesh, P())
    if isinstance(x, (jnp.ndarray, jax.ShapeDtypeStruct)) or hasattr(x, "shape"):
        return rep
    return jax.tree.map(lambda _: rep, x)


def _axes_size(mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def _largest_dividing(mesh, candidates, dim: int) -> Optional[Tuple[str, ...]]:
    """First candidate axis-tuple whose total size divides ``dim``."""
    for axes in candidates:
        axes = tuple(axes)
        if axes and dim % _axes_size(mesh, axes) == 0:
            return axes
    return None


def _batch_candidates(mesh, strategy: str):
    names = tuple(mesh.axis_names)
    if strategy == "fsdp":
        full = names
        cands = [full,
                 tuple(a for a in full if a != "pod"),
                 tuple(a for a in full if a != "model"),
                 tuple(a for a in full if a not in ("pod", "model"))]
        cands += [(a,) for a in full]
        return cands
    dp = batch_axes(mesh)
    return [dp] + [(a,) for a in dp]


def data_sharding(batch: Any, mesh, strategy: str = "tp") -> Any:
    """Shard every batch leaf's leading dim over the (strategy) batch axes."""
    cands = _batch_candidates(mesh, strategy)

    def one(leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        axes = _largest_dividing(mesh, cands, leaf.shape[0])
        if axes is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axes, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(one, batch)


# --------------------------------------------------------------------------
# PartitionSpec (de)serialization + cross-mesh adaptation (ckpt restore)
# --------------------------------------------------------------------------

def spec_to_json(spec) -> list:
    """JSON-able form of a PartitionSpec: one entry per dim, each ``None``
    or a list of mesh axis names (a single axis is stored as a 1-list)."""
    out = []
    for dim in tuple(spec):
        if dim is None:
            out.append(None)
        elif isinstance(dim, (tuple, list)):
            out.append([str(a) for a in dim])
        else:
            out.append([str(dim)])
    return out


def spec_from_json(doc) -> P:
    """Invert :func:`spec_to_json`."""
    dims = []
    for dim in doc or []:
        if dim is None:
            dims.append(None)
        elif len(dim) == 1:
            dims.append(dim[0])
        else:
            dims.append(tuple(dim))
    return P(*dims)


def adapt_spec(spec, mesh, shape: Sequence[int]) -> P:
    """Re-target a saved PartitionSpec onto a (possibly different) mesh.

    Restoring a checkpoint written on another mesh shape keeps the saved
    layout intent but must stay legal: axes the new mesh does not have are
    dropped, and an axis group whose total size no longer divides the dim
    is dropped too (same divisibility-guard policy as the sharding rules).
    """
    dims = []
    for i, dim in enumerate(tuple(spec)[: len(shape)]):
        axes = () if dim is None else (
            tuple(dim) if isinstance(dim, (tuple, list)) else (dim,))
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if axes and shape[i] % _axes_size(mesh, axes) == 0:
            dims.append(axes if len(axes) > 1 else axes[0])
        else:
            dims.append(None)
    return P(*dims)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        elif hasattr(k, "name"):
            names.append(str(k.name))
    return tuple(names)


def _model_spec_for(name: str, top_level: bool, trailing: Tuple[int, ...],
                    cfg) -> Tuple[Optional[str], ...]:
    """'model'-axis placement for the trailing (un-stacked) dims."""
    nd = len(trailing)
    spec = [None] * nd
    if nd < 2:
        return tuple(spec)
    if nd >= 3 and cfg.num_experts > 0 and trailing[0] == cfg.num_experts:
        spec[0] = "model"                       # expert parallelism
        return tuple(spec)
    if name == "embed":
        spec[0] = "model"                       # vocab-parallel embedding
        return tuple(spec)
    if name == "w_out" and top_level:
        spec[-1] = "model"                      # LM head: vocab dim
        return tuple(spec)
    if name == "w_router":
        return tuple(spec)                      # router stays replicated
    if name in _COL:
        spec[-1] = "model"
        return tuple(spec)
    if name in _ROW:
        spec[-2] = "model"
        return tuple(spec)
    # fallback: shard the largest trailing dim
    spec[int(max(range(nd), key=lambda i: trailing[i]))] = "model"
    return tuple(spec)


def param_shardings(params: Any, cfg, mesh) -> Any:
    """NamedSharding tree for a parameter (ShapeDtypeStruct) tree.

    Handles both the per-layer leaves and the vmap-stacked ``groups``
    leaves (their extra leading group dim is never sharded).
    """
    fsdp = (getattr(cfg, "sharding_strategy", "tp") == "fsdp"
            or getattr(cfg, "fsdp", False))
    daxes = batch_axes(mesh)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        stacked = bool(names) and names[0] == "groups"
        shape = tuple(leaf.shape)
        trailing = shape[1:] if stacked and len(shape) > 1 else shape
        lead = (None,) if stacked and len(shape) > 1 else ()

        spec = list(_model_spec_for(name, len(names) == 1, trailing, cfg))
        # divisibility guard on the model axis
        for i, s in enumerate(spec):
            if s == "model" and trailing[i] % mesh.shape["model"] != 0:
                spec[i] = None
        if fsdp and len(trailing) >= 2:
            # fully-shard: put the data axes on the largest still-free dim
            free = [i for i in range(len(trailing)) if spec[i] is None]
            free.sort(key=lambda i: -trailing[i])
            for i in free:
                axes = _largest_dividing(
                    mesh, [daxes] + [(a,) for a in daxes], trailing[i])
                if axes is not None:
                    spec[i] = axes if len(axes) > 1 else axes[0]
                    break
        return NamedSharding(mesh, P(*lead, *spec))

    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------------
# Decode / prefill caches
# --------------------------------------------------------------------------

def _cache_leaf(leaf, mesh, stacked: bool) -> NamedSharding:
    shape = tuple(leaf.shape)
    trailing = shape[1:] if stacked and len(shape) > 1 else shape
    lead = (None,) if stacked and len(shape) > 1 else ()
    if len(trailing) < 2:                       # pos / next_pos bookkeeping
        return NamedSharding(mesh, P())
    spec = [None] * len(trailing)
    daxes = _largest_dividing(
        mesh, [batch_axes(mesh)] + [(a,) for a in batch_axes(mesh)],
        trailing[0])
    if daxes is not None:
        spec[0] = daxes if len(daxes) > 1 else daxes[0]
    # (B, S, Hkv, Dh) kv caches / (B, H, Dh, Dh) wkv states: heads on model
    if len(trailing) == 4 and trailing[2] % mesh.shape["model"] == 0:
        spec[2] = "model"
    elif len(trailing) == 4 and trailing[1] % mesh.shape["model"] == 0:
        spec[1] = "model"
    return NamedSharding(mesh, P(*lead, *spec))


def cache_shardings(caches: Any, cfg, mesh) -> Any:
    """Shardings for the (stacked group caches, tail cache list) pair."""
    gcaches, tcaches = caches
    g_sh = (None if gcaches is None else
            jax.tree.map(lambda l: _cache_leaf(l, mesh, stacked=True),
                         gcaches))
    t_sh = jax.tree.map(lambda l: _cache_leaf(l, mesh, stacked=False),
                        tcaches)
    return (g_sh, t_sh)
