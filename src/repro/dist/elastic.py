"""Elastic mesh rebuilding: largest valid (data, model) mesh after device
loss.

When devices drop mid-job (preemption, hardware fault) the training loop
rebuilds the largest mesh the surviving devices support and re-shards.
The policy maximizes the number of devices actually used, breaking ties
toward more model parallelism (keeping the memory-per-device budget):
with 7 survivors and a requested model_parallel of 4, a (1, 4) mesh would
idle 3 devices while (7, 1) uses all 7 — so (7, 1) wins.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from jax.sharding import Mesh


class DeviceLoss(RuntimeError):
    """A device / host dropped out mid-run.

    Raised by the fault-injection harness (``repro.faults``) or by a
    cluster watchdog translating a hardware event; ``train.loop`` catches
    it and runs mid-run elastic recovery (roll back to the last committed
    checkpoint, rebuild the largest valid mesh, reshard, re-jit).

    ``survivors`` is the explicit list of devices still alive; ``keep``
    is the first-N shorthand the simulator uses (the loop resolves it
    against its own device list).  Both None means "same devices, soft
    restart" — a straggler escalation rather than real hardware loss.
    """

    def __init__(self, message: str = "device loss", survivors=None,
                 keep: Optional[int] = None) -> None:
        super().__init__(message)
        self.survivors = survivors
        self.keep = keep


def mesh_shape_dict(mesh) -> Dict[str, int]:
    """``{axis_name: size}`` for a mesh — the form the v2 checkpoint
    manifest records and the elastic restart path compares."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def largest_mesh_shape(n_devices: int, model_parallel: int
                       ) -> Tuple[int, int]:
    """Largest (data, model) shape for ``n_devices`` with model parallel
    at most ``model_parallel`` (reduced when it cannot be filled)."""
    assert n_devices >= 1 and model_parallel >= 1
    best = (1, 1)
    best_used = 1
    for mp in range(min(model_parallel, n_devices), 0, -1):
        data = n_devices // mp
        used = data * mp
        if used > best_used:
            best, best_used = (data, mp), used
    return best


def rebuild_mesh(devices: Sequence, model_parallel: int = 1) -> Mesh:
    """Build the largest valid ('data', 'model') mesh from ``devices``.

    Surplus devices that do not fill a full data row are left out (they
    rejoin at the next rebuild); the device order is preserved so data
    shards stay adjacent on the interconnect.
    """
    devices = list(devices)
    data, model = largest_mesh_shape(len(devices), model_parallel)
    grid = np.asarray(devices[: data * model], dtype=object).reshape(
        data, model)
    return Mesh(grid, ("data", "model"))
