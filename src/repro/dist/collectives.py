"""Error-bounded compressed gradient collectives (beyond-paper §Perf).

The DP gradient all-reduce is the dominant wire cost of data-parallel
training.  Here the paper's SZp linear quantizer (core/quantize) runs on
the wire instead of on disk, in the spirit of hZCCL/TopoSZ homomorphic
compressed collectives:

  * every DP member quantizes its local gradient leaf with the SAME
    absolute bound  eb = rel_eb * pmax(|g + err|)  (one scalar pmax per
    leaf makes the codebooks identical across members),
  * the all-reduce sums the int32 bin INDICES — summation commutes with
    the linear dequantizer, so  dequant(sum q_i) == sum dequant(q_i)
    exactly (the homomorphism), and the result differs from the direct
    sum by at most  n_members * eb  per element,
  * an error-feedback accumulator carries each member's local residual
    ``(g + err) - dequant(q)`` into the next step, so the compression
    error does not accumulate over training (EF-SGD).

The wire width of the codes (vs 16-bit bf16 values) is what
``code_bits`` accounts; benchmarks/bench_grad_compress.py reports the
resulting byte reduction.  core/bitpack packs the codes for the on-disk
format; on the wire the dry-run costs them at ``code_bits`` per value.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.quantize import dequantize, quantize
from repro.utils import bitwidth

AxisNames = Union[str, Sequence[str]]

# eb floor: keeps all-zero leaves (fresh error feedback, frozen params)
# from dividing by zero; anything at this scale quantizes to code 0.
_EB_TINY = 1e-30


def _leaf_eb(x: jnp.ndarray, rel_eb: float,
             axes: Optional[AxisNames] = None) -> jnp.ndarray:
    """Per-leaf absolute bound; pmax-shared so codebooks match across DP."""
    scale = jnp.max(jnp.abs(x))
    if axes:
        scale = jax.lax.pmax(scale, axes)
    return jnp.maximum(scale * rel_eb, _EB_TINY)


def code_bits(g: jnp.ndarray, rel_eb: float) -> jnp.ndarray:
    """Bits/value the quantized codes of ``g`` need (incl. sign bit)."""
    g = g.astype(jnp.float32)
    eb = _leaf_eb(g, rel_eb)
    q = quantize(g, eb)
    return bitwidth(jnp.max(jnp.abs(q)).astype(jnp.uint32)) + 1


def quantize_dequantize_sum(xs: jnp.ndarray, rel_eb: float
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Homomorphic sum of ``xs[i]`` through the quantizer vs the direct sum.

    xs: (n_members, ...) stacked per-member values.  Returns
    ``(dequant(sum_i quant(xs[i])), sum_i xs[i])``; the two differ by at
    most ``n_members * rel_eb * max|xs|`` per element.
    """
    xs = xs.astype(jnp.float32)
    eb = _leaf_eb(xs, rel_eb)
    q = quantize(xs, eb)
    homo = dequantize(q.sum(axis=0), eb)
    return homo, xs.sum(axis=0)


def compressed_psum_tree(grads: Any, axes: AxisNames, rel_eb: float = 1e-3,
                         err: Optional[Any] = None) -> Tuple[Any, Any]:
    """Error-bounded compressed psum over a gradient pytree.

    Must run inside a shard_map context where ``axes`` are manual mesh
    axes.  Returns ``(mean gradient tree, new error-feedback tree)``; the
    mean differs from the direct ``pmean`` by at most ``rel_eb *
    pmax|g + err|`` per leaf element (n_members * eb summed, / n_members).
    """
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axes)

    def one(g: jnp.ndarray, e: Optional[jnp.ndarray]):
        g32 = g.astype(jnp.float32)
        ge = g32 if e is None else g32 + e.astype(jnp.float32)
        eb = _leaf_eb(ge, rel_eb, axes)
        q = quantize(ge, eb)
        deq = dequantize(q, eb)
        gbar = dequantize(jax.lax.psum(q, axes), eb) / n
        new_e = ge - deq
        return gbar.astype(g.dtype), new_e

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = ([None] * len(leaves_g) if err is None
                else jax.tree.leaves(err))
    pairs = [one(g, e) for g, e in zip(leaves_g, leaves_e)]
    new_g = treedef.unflatten([p[0] for p in pairs])
    if err is None:
        new_e = treedef.unflatten([p[1] for p in pairs])
    else:
        new_e = treedef.unflatten([p[1].astype(e.dtype)
                                   for p, e in zip(pairs, leaves_e)])
    return new_g, new_e
