"""Error-bounded compressed gradient collectives (beyond-paper §Perf).

The DP gradient all-reduce is the dominant wire cost of data-parallel
training.  Here the paper's SZp linear quantizer (core/quantize) runs on
the wire instead of on disk, in the spirit of hZCCL/TopoSZ homomorphic
compressed collectives:

  * every DP member quantizes its local gradient leaf with the SAME
    absolute bound  eb = rel_eb * pmax(|g + err|)  (one scalar pmax per
    leaf makes the codebooks identical across members),
  * the all-reduce sums the int32 bin INDICES — summation commutes with
    the linear dequantizer, so  dequant(sum q_i) == sum dequant(q_i)
    exactly (the homomorphism), and the result differs from the direct
    sum by at most  n_members * eb  per element,
  * an error-feedback accumulator carries each member's local residual
    ``(g + err) - dequant(q)`` into the next step, so the compression
    error does not accumulate over training (EF-SGD).

Topology-aware variant (``topo_compressed_psum_tree``): the paper keeps
field extrema exact because flattening them destroys the topology users
analyze; the gradient analogue is the top-|g| tail that drives optimizer
updates.  Each member detects its local protected tail (top-k by
|g + err|, k from ``topo_frac`` — the strict-comparison selection idiom
of core/critical_points.py applied along the magnitude axis), the union
of protected indices is all-gathered, every member's EXACT fp32 value at
every union index is psum'd as a sparse (index, value) sidecar, and a
post-sum restore pass pins the summed gradient to those exact sums —
mirroring kernels/extrema_restore.py pinning field extrema.  Protected
entries are therefore bit-exact (their relative rank order — the
core/relative_order.py invariant — is preserved for free) while the
quantized body keeps the ``n_members * eb`` homomorphic bound.

The wire width of the codes (vs 16-bit bf16 values) is what
``code_bits`` accounts; ``sidecar_bits``/``topo_wire_bits`` add the
sparse sidecar cost and benchmarks/bench_grad_compress.py reports the
resulting byte reduction.  With ``wire_format="int32"`` the psum still
moves full int32 codes and the win is accounting-only; with
``wire_format="packed"`` the collective runs dist/ring.py's bitpacked
ppermute ring all-reduce and the packed uint8 buffers ARE the wire (the
dryrun's HLO collective-permute parse costs the actual packed bytes
moved per hop).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.quantize import dequantize, quantize
from repro.utils import bitwidth

AxisNames = Union[str, Sequence[str]]

# eb floor: keeps all-zero leaves (fresh error feedback, frozen params)
# from dividing by zero; anything at this scale quantizes to code 0.
_EB_TINY = 1e-30

# Sidecar wire widths: int32 flat indices, fp32 exact values.
SIDECAR_INDEX_BITS = 32
SIDECAR_VALUE_BITS = 32

INT32_MAX = 2**31 - 1

# wire formats for the compressed collective: "int32" moves full int32
# codes through jax.lax.psum (accounting-only win); "packed" runs the
# bitpacked ppermute ring all-reduce of dist/ring.py.
WIRE_FORMATS = ("int32", "packed")


def max_code(rel_eb: float) -> int:
    """Static bound on any per-member code magnitude at ``rel_eb``.

    ``|q| = |floor((x + eb) / (2 eb))| <= max|x| / (2 eb) + 1`` and the
    pmax-shared ``eb = rel_eb * pmax|x|`` gives ``|q| <= 1/(2 rel_eb) + 1``
    (+1 slack for f32 rounding of the encoder).  Known at trace time, so
    overflow handling below is static.
    """
    return int(1.0 / (2.0 * rel_eb)) + 2


def _leaf_eb(x: jnp.ndarray, rel_eb: float,
             axes: Optional[AxisNames] = None) -> jnp.ndarray:
    """Per-leaf absolute bound; pmax-shared so codebooks match across DP."""
    scale = jnp.max(jnp.abs(x))
    if axes:
        scale = jax.lax.pmax(scale, axes)
    return jnp.maximum(scale * rel_eb, _EB_TINY)


def _check_code_range(rel_eb: float) -> int:
    """Trace-time guard: per-member codes themselves must fit int32."""
    q_max = max_code(rel_eb)
    if q_max > INT32_MAX:
        raise ValueError(
            f"rel_eb={rel_eb:g} is too small: per-member codes reach "
            f"~{q_max:.3g} and overflow int32 in quantize() before any "
            f"sum; use rel_eb > {1.0 / (2.0 * (INT32_MAX - 2)):.2g}")
    return q_max


# hi/lo widening limit: the lo sums reach n * (2**16 - 1), which itself
# overflows int32 past this member count.
_MAX_WIDEN_MEMBERS = 32768


def _split_hi_lo(q: jnp.ndarray, n_members: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact int32 -> (hi, lo) with q == hi * 2**16 + lo, 0 <= lo < 2**16.

    Summing hi and lo separately widens the accumulation: member sums
    stay exact where a raw int32 sum of large codes (tiny ``rel_eb``)
    silently wraps — up to ``_MAX_WIDEN_MEMBERS`` members, past which
    the lo sums would wrap too, so that raises instead of degrading to
    the silent-wrap class this widening exists to close.
    """
    if n_members > _MAX_WIDEN_MEMBERS:
        raise ValueError(
            f"hi/lo-widened code sum supports at most "
            f"{_MAX_WIDEN_MEMBERS} members (lo sums would overflow "
            f"int32); got {n_members} — raise rel_eb so codes fit a raw "
            f"int32 sum, or reduce the data-parallel degree")
    return q >> 16, q & 0xFFFF


def _dequantize_wide(hi_sum: jnp.ndarray, lo_sum: jnp.ndarray,
                     eb: jnp.ndarray) -> jnp.ndarray:
    """Dequantize a hi/lo-widened code sum: (hi*2**16 + lo) * 2eb in f32."""
    two_eb = 2.0 * eb
    return (hi_sum.astype(jnp.float32) * (two_eb * 65536.0)
            + lo_sum.astype(jnp.float32) * two_eb)


def _residual(ge: jnp.ndarray, deq: jnp.ndarray) -> jnp.ndarray:
    """Error-feedback residual ``ge - deq`` with pinned f32 rounding.

    Written naively, XLA may contract the subtract with the multiply
    inside ``deq = q * 2eb`` into an FMA — or not — depending on fusion
    context, so the int32 and packed wire formats could disagree in the
    last ulp of the error feedback.  Adding ``ge * 0.0`` (never folded
    under default float semantics, and exactly zero here) makes the
    subtrahend an add rather than a mul, which pins both lowerings to
    the same double-rounded result.
    """
    return ge - (deq + ge * 0.0)


def code_bits(g: jnp.ndarray, rel_eb: float) -> jnp.ndarray:
    """Bits/value the quantized codes of ``g`` need (incl. sign bit)."""
    g = g.astype(jnp.float32)
    eb = _leaf_eb(g, rel_eb)
    q = quantize(g, eb)
    return bitwidth(jnp.max(jnp.abs(q)).astype(jnp.uint32)) + 1


# --------------------------------------------------------------------------
# Topology-aware protection: static sizing + wire accounting
# --------------------------------------------------------------------------

def protect_k(size: int, topo_frac: float) -> int:
    """Protected-tail length for a leaf of ``size`` elements (static).

    ``topo_frac <= 0`` disables protection; otherwise at least one entry
    per (non-empty) leaf is protected — every leaf has a largest
    component, the way every field has at least one extremum.
    """
    if topo_frac <= 0.0:
        return 0
    return min(size, max(1, int(math.ceil(topo_frac * size))))


def sidecar_bits(size: int, topo_frac: float, n_members: int) -> int:
    """Per-member wire bits of the exact sidecar for one leaf.

    One all-gather of the k local protected indices (k * 32 bits sent per
    member) plus one fp32 psum over the gathered union of n*k candidate
    entries (n * k * 32 bits moved per member).
    """
    k = protect_k(size, topo_frac)
    return k * SIDECAR_INDEX_BITS + n_members * k * SIDECAR_VALUE_BITS


def topo_wire_bits(g: jnp.ndarray, rel_eb: float, topo_frac: float,
                   n_members: int) -> float:
    """Total per-member wire bits for one leaf: quantized body + sidecar."""
    body = int(code_bits(g, rel_eb)) * g.size
    return body + sidecar_bits(g.size, topo_frac, n_members)


def topk_rank_preservation(direct: jnp.ndarray, approx: jnp.ndarray,
                           k: int) -> float:
    """Fraction of the top-k |direct| entries whose value rank survives.

    Ranks come from a double argsort over the selected entries (the dense
    ranking idiom of core/relative_order.py); an entry counts as preserved
    when its descending-value rank in ``approx`` equals its rank in
    ``direct``.  ``k`` is clamped to the flattened size (callers often
    pass a tree-level k to small leaves); ``k <= 0`` vacuously preserves
    everything and returns 1.0.
    """
    d = direct.reshape(-1).astype(jnp.float32)
    a = approx.reshape(-1).astype(jnp.float32)
    k = min(int(k), d.shape[0])
    if k <= 0:
        return 1.0
    idx = jax.lax.top_k(jnp.abs(d), k)[1]
    dvals, avals = d[idx], a[idx]
    drank = jnp.argsort(jnp.argsort(-dvals))
    arank = jnp.argsort(jnp.argsort(-avals))
    return float(jnp.mean((drank == arank).astype(jnp.float32)))


# --------------------------------------------------------------------------
# Homomorphic sums (stacked-member form, used by tests/benchmarks)
# --------------------------------------------------------------------------

def quantize_dequantize_sum(xs: jnp.ndarray, rel_eb: float
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Homomorphic sum of ``xs[i]`` through the quantizer vs the direct sum.

    xs: (n_members, ...) stacked per-member values.  Returns
    ``(dequant(sum_i quant(xs[i])), sum_i xs[i])``; the two differ by at
    most ``n_members * rel_eb * max|xs|`` per element.

    At tiny ``rel_eb`` per-member codes reach ``~1/(2 rel_eb)`` and a raw
    int32 sum over the members silently wraps; when ``n * max_code`` can
    exceed int32 the accumulation is widened via a hi/lo split (the sums
    stay exact; only the final fp32 dequantization rounds).
    """
    xs = xs.astype(jnp.float32)
    n = xs.shape[0]
    q_max = _check_code_range(rel_eb)
    eb = _leaf_eb(xs, rel_eb)
    q = quantize(xs, eb)
    if n * q_max > INT32_MAX:
        hi, lo = _split_hi_lo(q, n)
        homo = _dequantize_wide(hi.sum(axis=0), lo.sum(axis=0), eb)
    else:
        homo = dequantize(q.sum(axis=0), eb)
    return homo, xs.sum(axis=0)


def topo_quantize_dequantize_sum(
        xs: jnp.ndarray, rel_eb: float, topo_frac: float
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Topology-aware homomorphic sum over stacked members.

    Single-process simulation of ``topo_compressed_psum_tree`` semantics:
    quantized body summed through the codes, protected union restored to
    the exact fp32 member sum.  Returns ``(topo_homo, direct, protected)``
    where ``protected`` is the (n_members * k,) union of per-member top-k
    flat indices (with duplicates).  ``topo_homo[protected]`` equals
    ``direct[protected]`` bit-exactly; everywhere else the
    ``n_members * eb`` body bound holds.
    """
    xs = xs.astype(jnp.float32)
    n = xs.shape[0]
    flat = xs.reshape(n, -1)
    size = flat.shape[1]
    k = protect_k(size, topo_frac)
    q_max = _check_code_range(rel_eb)
    eb = _leaf_eb(xs, rel_eb)
    q = quantize(flat, eb)
    if n * q_max > INT32_MAX:
        hi, lo = _split_hi_lo(q, n)
        body = _dequantize_wide(hi.sum(axis=0), lo.sum(axis=0), eb)
    else:
        body = dequantize(q.sum(axis=0), eb)
    direct = flat.sum(axis=0)
    if k == 0:
        protected = jnp.zeros((0,), jnp.int32)
        return body.reshape(xs.shape[1:]), direct.reshape(xs.shape[1:]), \
            protected
    idx = jax.lax.top_k(jnp.abs(flat), k)[1]          # (n, k) local tails
    protected = idx.reshape(-1)                       # gathered union
    exact = flat[:, protected].sum(axis=0)            # fp32 sidecar psum
    topo = body.at[protected].set(exact)
    return topo.reshape(xs.shape[1:]), direct.reshape(xs.shape[1:]), protected


# --------------------------------------------------------------------------
# In-mesh collectives (shard_map manual-axes context)
# --------------------------------------------------------------------------

def _psum_leaf(g: jnp.ndarray, e: Optional[jnp.ndarray],
               axes: Tuple[str, ...], n: jnp.ndarray, rel_eb: float,
               topo_frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One leaf of the (optionally topo-protected) compressed mean-psum."""
    if g.size == 0:        # degenerate leaf: nothing on the wire
        return g, jnp.zeros(g.shape, jnp.float32)
    g32 = g.astype(jnp.float32)
    ge = g32 if e is None else g32 + e.astype(jnp.float32)
    eb = _leaf_eb(ge, rel_eb, axes)
    flat = ge.reshape(-1)
    q = quantize(flat, eb)
    deq = dequantize(q, eb)
    q_max = _check_code_range(rel_eb)
    n_static = int(jax.lax.psum(1, axes))     # static member count
    if n_static * q_max > INT32_MAX:
        # tiny rel_eb: an int32 psum of the codes would silently wrap and
        # break the n*eb bound — psum a hi/lo split instead (exact sums,
        # 2x code wire; wire_format="packed" raises rather than widen).
        hi, lo = _split_hi_lo(q, n_static)
        gsum = _dequantize_wide(jax.lax.psum(hi, axes),
                                jax.lax.psum(lo, axes), eb)
    else:
        gsum = dequantize(jax.lax.psum(q, axes), eb)
    new_e = _residual(flat, deq)
    k = protect_k(flat.shape[0], topo_frac)
    if k > 0:
        # CD stage on the gradient: each member's local protected tail.
        idx = jax.lax.top_k(jnp.abs(flat), k)[1]
        # Union of tails (identical on every member), then the exact fp32
        # sidecar: every member contributes its own value at EVERY union
        # index, so the psum'd entry is the true sum — not just the sum of
        # the members that happened to protect it.
        union = jax.lax.all_gather(idx, axes, tiled=True)
        exact = jax.lax.psum(flat[union], axes)
        # RP^-style restore: pin protected entries to their exact sums
        # (duplicate union indices carry identical values, so the scatter
        # is order-independent).
        gsum = gsum.at[union].set(exact)
        # Exact transmission leaves no local residual at protected entries.
        new_e = new_e.at[union].set(0.0)
    gbar = (gsum / n).reshape(g.shape)
    return gbar.astype(g.dtype), new_e.reshape(g.shape)


def _obs_int32_wire(sizes: Sequence[int], axes: Tuple[str, ...],
                    rel_eb: float, topo_frac: float) -> None:
    """Trace-time static wire model of the int32-code psum, recorded as
    last-write gauges (``_psum_tree`` executes once per trace, so
    counters would count compilations, not steps — which is exactly what
    ``collectives.traces`` does count)."""
    if not obs.enabled():
        return
    n = int(jax.lax.psum(1, axes))
    widen = 2.0 if n * max_code(rel_eb) > INT32_MAX else 1.0
    sizes = [s for s in sizes if s > 0]
    side = sum(sidecar_bits(s, topo_frac, n) for s in sizes) / 8.0
    obs.gauge_set("collectives.n_members", n)
    obs.gauge_set("collectives.leaves", len(sizes))
    obs.gauge_set("collectives.elems_per_step", sum(sizes))
    obs.gauge_set("collectives.int32_body_bytes_per_step",
                  4.0 * sum(sizes) * widen)
    obs.gauge_set("collectives.sidecar_bytes_per_step", side)
    obs.counter_add("collectives.traces", 1)


def _psum_tree(grads: Any, axes: AxisNames, rel_eb: float,
               err: Optional[Any], topo_frac: float,
               wire_format: str = "int32") -> Tuple[Any, Any]:
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    if wire_format not in WIRE_FORMATS:
        raise ValueError(f"unknown wire_format {wire_format!r}; "
                         f"expected one of {WIRE_FORMATS}")
    if wire_format == "packed":
        from repro.dist.ring import packed_psum_tree   # lazy: circular import
        return packed_psum_tree(grads, axes, rel_eb, err, topo_frac)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axes)
    leaves_g, treedef = jax.tree.flatten(grads)
    _obs_int32_wire([g.size for g in leaves_g], axes, rel_eb, topo_frac)
    leaves_e = ([None] * len(leaves_g) if err is None
                else jax.tree.leaves(err))
    pairs = [_psum_leaf(g, e, axes, n, rel_eb, topo_frac)
             for g, e in zip(leaves_g, leaves_e)]
    new_g = treedef.unflatten([p[0] for p in pairs])
    if err is None:
        new_e = treedef.unflatten([p[1] for p in pairs])
    else:
        new_e = treedef.unflatten([p[1].astype(e.dtype)
                                   for p, e in zip(pairs, leaves_e)])
    return new_g, new_e


def compressed_psum_tree(grads: Any, axes: AxisNames, rel_eb: float = 1e-3,
                         err: Optional[Any] = None,
                         wire_format: str = "int32") -> Tuple[Any, Any]:
    """Error-bounded compressed psum over a gradient pytree.

    Must run inside a shard_map context where ``axes`` are manual mesh
    axes.  Returns ``(mean gradient tree, new error-feedback tree)``; the
    mean differs from the direct ``pmean`` by at most ``rel_eb *
    pmax|g + err|`` per leaf element (n_members * eb summed, / n_members).

    ``wire_format="packed"`` swaps the int32 code psum for the bitpacked
    ppermute ring all-reduce (dist/ring.py) — same results, actual packed
    bytes on the wire.
    """
    return _psum_tree(grads, axes, rel_eb, err, topo_frac=0.0,
                      wire_format=wire_format)


def topo_compressed_psum_tree(grads: Any, axes: AxisNames,
                              rel_eb: float = 1e-3, topo_frac: float = 1e-3,
                              err: Optional[Any] = None,
                              wire_format: str = "int32") -> Tuple[Any, Any]:
    """Topology-aware compressed psum: exact top-|g| tail + bounded body.

    Same contract as :func:`compressed_psum_tree` plus, per leaf, the
    union of per-member top-``protect_k(size, topo_frac)`` entries (by
    ``|g + err|``) is transmitted exactly in fp32 and restored after the
    sum.  Guarantees, per leaf:

      (a) body: ``|mean - pmean| <= rel_eb * pmax|g + err|`` elementwise,
      (b) every protected entry equals the exact fp32 member mean — hence
          the relative rank order of the protected tail is preserved
          (modulo the final cast back to the input gradient dtype, which
          is monotone).

    Wire cost: ``code_bits`` per body value plus ``sidecar_bits(size,
    topo_frac, n_members)`` per member per leaf (< 5% overhead at
    ``topo_frac = 1e-3`` for typical 8–12-bit bodies).  With
    ``wire_format="packed"`` the sidecar's (index, value) pairs ride the
    bitpacked ring buffers instead of a separate all-gather + psum.
    """
    return _psum_tree(grads, axes, rel_eb, err, topo_frac=topo_frac,
                      wire_format=wire_format)
