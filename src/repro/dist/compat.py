"""shard_map across JAX versions.

The distributed layer is written against the modern ``jax.shard_map``
surface (``axis_names=...`` selects the manual axes, ``check_vma``
toggles the varying-manual-axes check).  Older JAX (<= 0.4.x, including
the 0.4.37 this repo pins) only ships ``jax.experimental.shard_map`` with
the inverse vocabulary: ``auto=frozenset(...)`` names the axes that STAY
automatic and the check flag is ``check_rep``.  This module translates.
"""
from __future__ import annotations

from typing import Optional, Set

import jax

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)

# Legacy partial-auto (auto=frozenset) is wired through below, but the XLA
# shipped with 0.4.x fatally asserts (`Check failed: IsManualSubgroup()`)
# when GSPMD re-partitions real model graphs inside a manual subgroup.
# Callers that can degrade (e.g. the compressed-DP train step runs fully
# manual, replicating model-axis compute per DP shard) should consult this.
HAS_PARTIAL_AUTO = _NEW_SHARD_MAP is not None


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None,
              check_vma: bool = False):
    """Version-portable shard_map.

    ``axis_names``: mesh axes made manual inside ``f`` (None = all of
    them).  ``check_vma=False`` disables the replication/VMA check, which
    the compressed-DP step needs (error-feedback state is genuinely
    device-varying).
    """
    if _NEW_SHARD_MAP is not None:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma,
                              **kw)
    from jax.experimental.shard_map import shard_map as _legacy
    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma, **kw)
