"""Bitpacked ppermute ring all-reduce: the compressed wire, realized.

``dist.collectives`` proves the byte win of quantized gradient codes but
(with ``wire_format="int32"``) still moves full int32 codes through
``jax.lax.psum`` — the compression exists only in the accounting.  This
module closes that gap, hZCCL-style: the collective itself operates on
the PACKED representation.

Ring schedule (single data-parallel axis, n members, n-1 hops):

  * every member quantizes its leaves exactly as the int32 path does
    (same pmax-shared eb, same codes), concatenates them into per-bucket
    code streams (small leaves share one stream per hop), and keeps a
    running partial sum ``msg`` (initially its own codes);
  * each hop packs ``msg`` with ``core.bitpack.pack_blocks`` at dynamic
    per-block widths under a STATIC per-hop cap — a partial sum over h
    members needs at most ``base_width(rel_eb) + ceil(log2(h))`` bits
    (``bitpack.sum_width``), because ``|q| <= 1/(2 rel_eb) + 2`` holds
    deterministically — appends the sign bitplane (``pack_bits``), the
    per-block width bytes, and the topo sidecar's fp32 values, ships the
    single uint8 buffer with ``jax.lax.ppermute``, unpacks, and adds its
    own codes to the received partial sum;
  * after n-1 hops every member holds the full integer code sum —
    bit-identical to ``jax.lax.psum`` of the codes, since integer
    addition commutes — and dequantizes once.

Topo sidecar: the per-member top-k indices circulate first (an index
pre-ring of k int32 per hop), giving every member the same member-ordered
union; each member's exact fp32 values at EVERY union index then ride the
packed body buffer, collected by origin.  The exact sums are folded in
member order 0..n-1 — on the CPU/TPU ring all-reduce this matches
``jax.lax.psum``'s reduction order bit-for-bit, which is what makes the
packed and int32 wire formats produce identical protected entries.

Overflow: the ring accumulates in int32 sign-magnitude (32 magnitude bits
+ separate sign plane); it requires ``n * max_code(rel_eb) <= int32 max``
and raises a clear trace-time error otherwise (the int32 psum path widens
via a hi/lo split instead — see ``collectives._psum_leaf``).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.bitpack import (pack_bits, sum_width, unpack_bits,
                                unpack_blocks)
from repro.core.quantize import dequantize, quantize
from repro.dist.collectives import (_EB_TINY, INT32_MAX, _check_code_range,
                                    _residual, max_code, protect_k)
from repro.kernels import ops
from repro.utils import bitwidth, cdiv

BLOCK_K = 256                 # values per packed block (one width byte each)
BUCKET_ELEMS = 1 << 20        # leaf-batching target: elements per bucket


def base_width(rel_eb: float) -> int:
    """Static magnitude bit width of any per-member code at ``rel_eb``."""
    return max(1, max_code(rel_eb).bit_length())


def ring_perm(n: int) -> List[Tuple[int, int]]:
    """Unidirectional ring permutation i -> i+1 (mod n)."""
    return [(i, (i + 1) % n) for i in range(n)]


def _axis_size(axes: Sequence[str]) -> int:
    """Static member count of the (manual) mesh axes."""
    return int(jax.lax.psum(1, tuple(axes)))


def _require_single_axis(axes: Sequence[str]) -> str:
    if len(axes) != 1:
        raise NotImplementedError(
            f"wire_format='packed' runs a ppermute ring over ONE "
            f"data-parallel axis; got {tuple(axes)}.  Use "
            f"wire_format='int32' on multi-axis (pod) meshes.")
    return axes[0]


# --------------------------------------------------------------------------
# byte views (version-portable: shifts, not narrowing bitcasts)
# --------------------------------------------------------------------------

def _u32_to_bytes(x: jnp.ndarray) -> jnp.ndarray:
    """(m,) uint32/int32 -> (4m,) uint8, little-endian."""
    x = x.astype(jnp.uint32)
    sh = (jnp.arange(4, dtype=jnp.uint32) * 8)[None, :]
    return ((x[:, None] >> sh) & jnp.uint32(0xFF)).astype(jnp.uint8).reshape(-1)


def _bytes_to_u32(b: jnp.ndarray) -> jnp.ndarray:
    """(4m,) uint8 -> (m,) uint32, little-endian."""
    b = b.reshape(-1, 4).astype(jnp.uint32)
    sh = (jnp.arange(4, dtype=jnp.uint32) * 8)[None, :]
    return (b << sh).sum(axis=1).astype(jnp.uint32)


def _f32_to_bytes(v: jnp.ndarray) -> jnp.ndarray:
    return _u32_to_bytes(jax.lax.bitcast_convert_type(v, jnp.uint32))


def _bytes_to_f32(b: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(_bytes_to_u32(b), jnp.float32)


# --------------------------------------------------------------------------
# in-mesh ring primitives (shard_map manual-axes context)
# --------------------------------------------------------------------------

def ring_gather(x: jnp.ndarray, axis: str, n: int) -> jnp.ndarray:
    """Circulate originals around the ring -> (n, *x.shape) by origin.

    Member-ordered like ``jax.lax.all_gather`` but ppermute-based, so the
    per-hop payload is exactly ``x`` (the index pre-ring of the packed
    sidecar).
    """
    i = jax.lax.axis_index(axis)
    out = jnp.zeros((n,) + x.shape, x.dtype).at[i].set(x)
    if n == 1:
        return out
    perm = ring_perm(n)
    msg = x
    for h in range(1, n):
        msg = jax.lax.ppermute(msg, axis, perm)
        origin = (i - h) % n
        out = out.at[origin].set(msg)
    return out


def ordered_fold(vals: jnp.ndarray) -> jnp.ndarray:
    """Sum (n, ...) by-origin values sequentially in member order 0..n-1.

    This is the reduction order ``jax.lax.psum`` realizes on the ring
    all-reduce, so folding this way keeps the packed path's fp32 sidecar
    sums bit-identical to the int32 path's psum.
    """
    out = vals[0]
    for j in range(1, vals.shape[0]):
        out = out + vals[j]
    return out


def ring_allreduce_codes(
        q: jnp.ndarray, axis: str, n: int, rel_eb: float,
        side_vals: Optional[jnp.ndarray] = None, block_k: int = BLOCK_K,
        backend: Optional[str] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], jnp.ndarray]:
    """Bitpacked ring all-reduce of int32 codes (+ fp32 sidecar circulation).

    Args:
      q: (P,) int32 per-member codes, P a multiple of ``block_k``, with
         ``n * max|q| <= int32 max`` (caller-guarded via ``max_code``).
      side_vals: optional (U,) fp32 — this member's exact values at the
         sidecar union; circulated by origin alongside the packed body.
      backend: kernels.ops backend for the per-hop BE pack (the same
         tiled local-pack + compaction kernels the resident compressor
         runs; ``None`` resolves to the hardware default).  Buffers are
         byte-identical across backends.

    Returns:
      (code_sum (P,) int32  — bit-identical to ``psum(q, axis)``,
       vals_by_origin (n, U) fp32 or None,
       valid_bytes () f32 — measured packed payload bytes this member
       actually needed across all hops; the shipped buffers are statically
       capped at the ``sum_width`` bound).
    """
    p = q.shape[0]
    if p % block_k != 0 or p % 8 != 0:
        raise ValueError(
            f"code length {p} must be a multiple of block_k={block_k} "
            f"and of 8 (sign-plane bytes); pad the stream first")
    backend = ops.resolve_backend(backend)
    b_blocks = p // block_k
    sign_bytes = p // 8
    w0 = base_width(rel_eb)
    i = jax.lax.axis_index(axis)
    u = 0 if side_vals is None else side_vals.shape[0]
    vout = None
    if side_vals is not None:
        vout = jnp.zeros((n, u), jnp.float32).at[i].set(side_vals)
    valid = jnp.float32(0.0)
    if n == 1:
        return q, vout, valid

    perm = ring_perm(n)
    msg = q                                   # partial sum, 1 member so far
    vmsg = side_vals                          # circulating originals
    for h in range(1, n):
        with jax.named_scope(f"ring.hop{h}"):
            w_cap = sum_width(w0, h)          # static per-hop width bound
            mag_cap = b_blocks * cdiv(block_k * w_cap, 8)
            mags = jnp.abs(msg).astype(jnp.uint32).reshape(b_blocks, block_k)
            widths = bitwidth(mags.max(axis=1))   # (B,) dynamic, <= w_cap
            local = ops.local_pack(mags, widths, max_width=w_cap,
                                   backend=backend)
            buf, _, total = ops.compact_bytes(local, widths, block_k,
                                              backend=backend)
            signs = pack_bits((msg < 0).astype(jnp.uint32))
            parts = [buf, signs, widths.astype(jnp.uint8)]
            if vmsg is not None:
                parts.append(_f32_to_bytes(vmsg))
            payload = jnp.concatenate(parts)
            valid = valid + (total.astype(jnp.float32)
                             + jnp.float32(sign_bytes + b_blocks + 4 * u))

            payload = jax.lax.ppermute(payload, axis, perm)

            o_sign = mag_cap
            o_width = o_sign + sign_bytes
            o_val = o_width + b_blocks
            rwidths = payload[o_width:o_val].astype(jnp.int32)
            rmags = unpack_blocks(payload[:mag_cap], rwidths,
                                  block_k).reshape(-1)
            rsigns = unpack_bits(payload[o_sign:o_width], p)
            rcodes = jnp.where(rsigns == 1, -rmags.astype(jnp.int32),
                               rmags.astype(jnp.int32))
            msg = rcodes + q                  # received h members + own
            if vmsg is not None:
                vmsg = _bytes_to_f32(payload[o_val:o_val + 4 * u])
                vout = vout.at[(i - h) % n].set(vmsg)
    return msg, vout, valid


# --------------------------------------------------------------------------
# tree-level packed psum (bucketed leaf batching)
# --------------------------------------------------------------------------

def _obs_wire(sizes: List[int], rel_eb: float, topo_frac: float, n: int,
              block_k: int, bucket_elems: int) -> None:
    """Trace-time wire accounting: absorb the static
    :func:`packed_wire_summary` model into the obs registry.

    ``packed_psum_tree`` executes ONCE per trace (inside shard_map/jit),
    never per step, so these must be last-write-wins GAUGES — an
    accumulating counter would record trace counts, not wire bytes.  The
    one true counter here (``ring.traces``) counts exactly that:
    compilations of the packed wire."""
    if not obs.enabled():
        return
    s = packed_wire_summary(sizes, rel_eb, topo_frac, n, block_k=block_k,
                            bucket_elems=bucket_elems)
    for k in ("n_members", "hops", "base_width_bits",
              "packed_bytes_per_hop", "packed_bytes_per_step",
              "sidecar_idx_bytes", "sidecar_val_bytes",
              "int32_bytes_per_hop", "int32_bytes_per_step",
              "packed_vs_int32_per_hop"):
        obs.gauge_set(f"ring.{k}", float(s[k]))
    obs.counter_add("ring.traces", 1)

def _bucket_leaves(sizes: List[int], bucket_elems: int) -> List[List[int]]:
    """Group leaf indices so each bucket packs ~bucket_elems values."""
    buckets, cur, cur_n = [], [], 0
    for li, sz in enumerate(sizes):
        if cur and cur_n + sz > bucket_elems:
            buckets.append(cur)
            cur, cur_n = [], 0
        cur.append(li)
        cur_n += sz
    if cur:
        buckets.append(cur)
    return buckets


def packed_psum_tree(grads: Any, axes: Sequence[str], rel_eb: float,
                     err: Optional[Any], topo_frac: float,
                     block_k: int = BLOCK_K,
                     bucket_elems: int = BUCKET_ELEMS,
                     backend: Optional[str] = None) -> Tuple[Any, Any]:
    """Compressed mean-psum over a pytree with the bitpacked ring wire.

    Same contract (and bit-identical results on the ring-ordered
    backends) as ``collectives._psum_tree(wire_format="int32")``: returns
    ``(mean gradient tree, new error-feedback tree)``.  Leaves are
    concatenated into buckets so small leaves share one packed stream per
    hop; the topo sidecar rides the body buffer (see module docstring).
    """
    axis = _require_single_axis(tuple(axes))
    n = _axis_size((axis,))
    if block_k % 8 != 0:
        raise ValueError(
            f"block_k={block_k} must be a multiple of 8: the payload "
            f"layout derives the sign-plane byte count from the padded "
            f"code length")
    q_max = _check_code_range(rel_eb)
    if n * q_max > INT32_MAX:
        raise ValueError(
            f"wire_format='packed': {n}-member partial code sums can reach "
            f"{n * q_max:.3g} > int32 max at rel_eb={rel_eb:g}; raise "
            f"rel_eb or use wire_format='int32' (which widens via a hi/lo "
            f"split)")

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = ([None] * len(leaves_g) if err is None
                else jax.tree.leaves(err))
    nf = jnp.float32(n)

    out: List[Optional[Tuple[jnp.ndarray, jnp.ndarray]]] = \
        [None] * len(leaves_g)
    work = []                              # non-empty leaf indices
    for li, g in enumerate(leaves_g):
        if g.size == 0:
            out[li] = (g, jnp.zeros(g.shape, jnp.float32))
        else:
            work.append(li)

    _obs_wire([leaves_g[li].size for li in work], rel_eb, topo_frac, n,
              block_k, bucket_elems)

    for bucket in _bucket_leaves([leaves_g[li].size for li in work],
                                 bucket_elems):
        lis = [work[j] for j in bucket]
        ge_l, eb_parts = [], []
        for li in lis:
            g32 = leaves_g[li].astype(jnp.float32).reshape(-1)
            e = leaves_e[li]
            ge = g32 if e is None else g32 + e.astype(jnp.float32).reshape(-1)
            ge_l.append(ge)
            eb_parts.append(jnp.max(jnp.abs(ge)))
        # one pmax for the whole bucket: per-leaf scalar scales stacked
        scales = jax.lax.pmax(jnp.stack(eb_parts), (axis,))
        ebs = jnp.maximum(scales * rel_eb, _EB_TINY)

        sizes = [ge.shape[0] for ge in ge_l]
        offs = [0]
        for sz in sizes:
            offs.append(offs[-1] + sz)
        q_l = [quantize(ge, ebs[j]) for j, ge in enumerate(ge_l)]
        deq_cat = jnp.concatenate(
            [dequantize(q, ebs[j]) for j, q in enumerate(q_l)])
        ge_cat = jnp.concatenate(ge_l)
        q_cat = jnp.concatenate(q_l)
        pad = (-q_cat.shape[0]) % block_k
        q_pad = jnp.pad(q_cat, (0, pad))

        side_vals, union = None, None
        ks = [protect_k(sz, topo_frac) for sz in sizes]
        if sum(ks) > 0:
            idx_l = [jax.lax.top_k(jnp.abs(ge), k)[1] + offs[j]
                     for j, (ge, k) in enumerate(zip(ge_l, ks)) if k > 0]
            own_idx = jnp.concatenate(idx_l)
            idx_all = ring_gather(own_idx, axis, n)      # (n, ktot) by origin
            union = idx_all.reshape(-1)                  # member-ordered
            side_vals = ge_cat[union]

        q_sum, vals_by_origin, _ = ring_allreduce_codes(
            q_pad, axis, n, rel_eb, side_vals=side_vals, block_k=block_k,
            backend=backend)
        q_sum = q_sum[:q_cat.shape[0]]

        gsum_cat = jnp.concatenate(
            [dequantize(q_sum[offs[j]:offs[j + 1]], ebs[j])
             for j in range(len(lis))])
        new_e_cat = _residual(ge_cat, deq_cat)
        if union is not None:
            exact = ordered_fold(vals_by_origin)         # == psum order
            gsum_cat = gsum_cat.at[union].set(exact)
            new_e_cat = new_e_cat.at[union].set(0.0)

        for j, li in enumerate(lis):
            g = leaves_g[li]
            sl = slice(offs[j], offs[j + 1])
            gbar = (gsum_cat[sl] / nf).reshape(g.shape).astype(g.dtype)
            out[li] = (gbar, new_e_cat[sl].reshape(g.shape))

    new_g = treedef.unflatten([p[0] for p in out])
    if err is None:
        new_e = treedef.unflatten([p[1] for p in out])
    else:
        new_e = treedef.unflatten([p[1].astype(e.dtype)
                                   for p, e in zip(out, leaves_e)])
    return new_g, new_e


# --------------------------------------------------------------------------
# wire accounting: static model + measured simulation (host-side)
# --------------------------------------------------------------------------

def packed_wire_summary(sizes: Sequence[int], rel_eb: float,
                        topo_frac: float, n_members: int,
                        block_k: int = BLOCK_K,
                        bucket_elems: int = BUCKET_ELEMS) -> dict:
    """Static bytes-shipped model of the packed ring for given leaf sizes.

    These are the ACTUAL ppermute payload sizes the compiled step moves
    per hop (the dryrun's HLO collective-permute parse sees the same
    buffers), not the ``code_bits * size`` estimate.  ``int32_*`` fields
    give the equivalent int32-ring reference for the same schedule.
    """
    sizes = [s for s in sizes if s > 0]
    w0 = base_width(rel_eb)
    hops = max(0, n_members - 1)
    body_hops = [0.0] * max(1, hops)
    idx_bytes = val_bytes = 0
    total_elems = 0
    for bucket in _bucket_leaves(list(sizes), bucket_elems):
        bsizes = [sizes[j] for j in bucket]
        p = sum(bsizes)
        p_pad = cdiv(p, block_k) * block_k
        b_blocks = p_pad // block_k
        ktot = sum(protect_k(sz, topo_frac) for sz in bsizes)
        u = n_members * ktot
        for h in range(1, hops + 1):
            w_cap = sum_width(w0, h)
            body_hops[h - 1] += (b_blocks * cdiv(block_k * w_cap, 8)
                                 + p_pad // 8 + b_blocks + 4 * u)
        idx_bytes += hops * 4 * ktot
        val_bytes += hops * 4 * u
        total_elems += p
    body_total = sum(body_hops) if hops else 0.0
    int32_hop = 4.0 * total_elems
    return {
        "n_members": n_members,
        "hops": hops,
        "base_width_bits": w0,
        "packed_bytes_per_hop": (body_total / hops if hops else 0.0),
        "packed_hop_bytes": [float(b) for b in (body_hops if hops else [])],
        "packed_bytes_per_step": float(body_total + idx_bytes),
        "sidecar_idx_bytes": float(idx_bytes),
        "sidecar_val_bytes": float(val_bytes),
        "int32_bytes_per_hop": int32_hop,
        "int32_bytes_per_step": float(hops * int32_hop + idx_bytes
                                      + val_bytes),
        "packed_vs_int32_per_hop": ((body_total / hops) / int32_hop
                                    if hops and int32_hop else 1.0),
    }


def simulate_hop_bytes(qs: jnp.ndarray, rel_eb: float,
                       block_k: int = BLOCK_K) -> dict:
    """Measured per-hop packed bytes for stacked member codes (no mesh).

    qs: (n, P) int32 codes (one row per member).  Replays the ring's
    partial-sum schedule on the host and packs every member's every-hop
    payload for real, returning mean measured (valid) and static shipped
    bytes per hop, plus the int32-ring reference.
    """
    n, p = qs.shape
    pad = (-p) % block_k
    qs = jnp.pad(qs.astype(jnp.int32), ((0, 0), (0, pad)))
    p_pad = p + pad
    b_blocks = p_pad // block_k
    w0 = base_width(rel_eb)
    fixed = p_pad // 8 + b_blocks            # sign plane + width bytes
    valid_hops, shipped_hops = [], []
    msg = qs                                  # row i: member i's partial sum
    for h in range(1, n):
        w_cap = sum_width(w0, h)
        mags = jnp.abs(msg).astype(jnp.uint32).reshape(n, b_blocks, block_k)
        widths = bitwidth(mags.max(axis=2))                   # (n, B)
        nbytes = (block_k * widths + 7) // 8
        valid_hops.append(float(jnp.mean(nbytes.sum(axis=1))) + fixed)
        shipped_hops.append(b_blocks * cdiv(block_k * w_cap, 8) + fixed)
        msg = jnp.roll(msg, 1, axis=0) + qs   # next partial sum per member
    int32_hop = 4.0 * p
    mean_valid = (sum(valid_hops) / len(valid_hops)) if valid_hops else 0.0
    mean_ship = (sum(shipped_hops) / len(shipped_hops)) if shipped_hops \
        else 0.0
    return {
        "hops": n - 1,
        "valid_bytes_per_hop": mean_valid,
        "shipped_bytes_per_hop": float(mean_ship),
        "int32_bytes_per_hop": int32_hop,
        "valid_vs_int32": mean_valid / int32_hop if int32_hop else 1.0,
        "shipped_vs_int32": mean_ship / int32_hop if int32_hop else 1.0,
    }
