"""Request admission/eviction for the continuous-batching engine.

A ``Request`` is one prompt + generation budget; the ``Scheduler`` keeps
the FIFO waiting queue and the slot -> ``RequestState`` map.  Admission
fills free slots in arrival order at the top of every engine step;
eviction frees a slot the moment its request finishes (EOS or budget),
mid-decode — the freed slot is eligible for admission on the next step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    inputs: Dict[str, np.ndarray]     # B=1 prompt batch (see configs/base)
    max_new_tokens: int
    eos_id: Optional[int] = None

    def prompt_len(self, cfg) -> int:
        """Number of cache positions the prompt occupies."""
        if cfg.frontend == "audio_frames":
            return int(self.inputs["embeds"].shape[1])
        n = int(self.inputs["tokens"].shape[1])
        if cfg.frontend == "vision_patches":
            n += int(self.inputs["patch_embeds"].shape[1])
        return n


@dataclasses.dataclass
class RequestState:
    req: Request
    slot: int
    prompt_len: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    admit_step: int = 0
    finish_step: int = -1

    @property
    def next_pos(self) -> int:
        """Cache write head: prompt plus every generated-token KV written
        so far (the latest token's KV lands during its decode step)."""
        return self.prompt_len + max(len(self.tokens) - 1, 0)

    def done(self) -> bool:
        if len(self.tokens) >= self.req.max_new_tokens:
            return True
        eos = self.req.eos_id
        return eos is not None and len(self.tokens) > 0 \
            and self.tokens[-1] == eos


class Scheduler:
    """FIFO continuous-batching scheduler over a fixed slot set."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, RequestState] = {}
        self.finished: List[RequestState] = []

    def add(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.active)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.num_slots) if s not in self.active]

    def admit(self, step: int, prompt_len_fn) -> List[RequestState]:
        """Move waiting requests into free slots (arrival order)."""
        admitted = []
        for slot in self.free_slots():
            if not self.waiting:
                break
            req = self.waiting.popleft()
            st = RequestState(req=req, slot=slot,
                              prompt_len=prompt_len_fn(req),
                              admit_step=step)
            self.active[slot] = st
            admitted.append(st)
        return admitted

    def evict_finished(self, step: int) -> List[RequestState]:
        """Retire every active request that has hit EOS or its budget."""
        out = []
        for slot in [s for s, st in self.active.items() if st.done()]:
            st = self.active.pop(slot)
            st.finish_step = step
            self.finished.append(st)
            out.append(st)
        return out

    def positions(self) -> Dict[int, int]:
        return {slot: st.next_pos for slot, st in self.active.items()}
