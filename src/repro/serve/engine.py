"""Serving engine: batched prefill + greedy decode over jit'd steps.

Prefill builds per-layer caches from a prompt batch, pads them out to
``max_len`` slots (global layers; local layers keep their ring window),
then the decode loop appends one token per step.  serve_step == one
decode_step — the function the decode_* dry-run shapes lower.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.attention import KVCache


def pad_caches(caches, max_len: int):
    """Grow every KVCache to ``max_len`` slots (rings stay window-sized)."""

    def pad_kv(c):
        if not isinstance(c, KVCache):
            return c                    # recurrent states carry no seq dim
        s = c.k.shape[-3]
        if s >= max_len:
            return c
        # ring caches (local layers) keep their size; only full caches grow.
        pad = max_len - s
        widths_kv = [(0, 0)] * c.k.ndim
        widths_kv[-3] = (0, pad)
        widths_pos = [(0, 0)] * c.pos.ndim
        widths_pos[-1] = (0, pad)
        return KVCache(
            k=jnp.pad(c.k, widths_kv),
            v=jnp.pad(c.v, widths_kv),
            pos=jnp.pad(c.pos, widths_pos, constant_values=-1),
            next_pos=c.next_pos,
        )

    return jax.tree.map(pad_kv, caches,
                        is_leaf=lambda x: isinstance(x, KVCache))


def is_ring(cfg, kind: str) -> bool:
    return kind == "local"


class ServeEngine:
    """Greedy batched generation for any registered arch."""

    def __init__(self, cfg, params, max_len: int = 4096):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(partial(lm.prefill, cfg=cfg))
        self._decode = jax.jit(partial(lm.decode_step, cfg=cfg))

    def generate(self, batch: Dict[str, jnp.ndarray], steps: int,
                 collect_logits: bool = False):
        """Returns generated tokens (B, steps) [+ final logits]."""
        logits, caches = self._prefill(self.params, batch=batch)
        caches = pad_caches(caches, self.max_len)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        outs = [tok]
        last_logits = logits
        for _ in range(steps - 1):
            tok, last_logits, caches = self._decode(self.params, tokens=tok,
                                                    caches=caches)
            outs.append(tok)
        tokens = jnp.concatenate(outs, axis=1)
        if collect_logits:
            return tokens, last_logits
        return tokens


def serve_step(params, cfg, tokens, caches):
    """The decode-shape dry-run entry point (one new token, big cache)."""
    return lm.decode_step(params, cfg, tokens, caches)
