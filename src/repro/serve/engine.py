"""Serving engines.

``ServeEngine`` is the greedy single-batch loop: batched prefill builds
per-layer caches from one prompt batch, pads them to ``max_len`` slots,
then the decode loop appends one token per step for every row until a
fixed step budget.  serve_step == one decode_step — the function the
decode_* dry-run shapes lower.

``ContinuousServeEngine`` is the production loop: requests are admitted
into free slots of a persistent slot-batched cache per step (B=1 prefill
written into the slot), decoded together with per-row positions, and
evicted the moment they finish; cold KV pages tier into error-bounded
compressed streams (see serve/paging.py).  With ``kv_mode="raw"`` each
request's tokens are bit-identical to the greedy engine run on that
request alone.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import lm
from repro.models.attention import KVCache
from repro.serve.paging import PagePool, cache_kind
from repro.serve.scheduler import Request, RequestState, Scheduler


def pad_caches(caches, max_len: int):
    """Grow every KVCache to ``max_len`` slots (masking by absolute
    position keeps ring-ordered prefill content valid in the grown
    buffer)."""

    def pad_kv(c):
        if not isinstance(c, KVCache):
            return c                    # recurrent states carry no seq dim
        s = c.k.shape[-3]
        if s >= max_len:
            return c
        pad = max_len - s
        widths_kv = [(0, 0)] * c.k.ndim
        widths_kv[-3] = (0, pad)
        widths_pos = [(0, 0)] * c.pos.ndim
        widths_pos[-1] = (0, pad)
        return KVCache(
            k=jnp.pad(c.k, widths_kv),
            v=jnp.pad(c.v, widths_kv),
            pos=jnp.pad(c.pos, widths_pos, constant_values=-1),
            next_pos=c.next_pos,
        )

    return jax.tree.map(pad_kv, caches,
                        is_leaf=lambda x: isinstance(x, KVCache))


def is_ring(cfg, kind: str) -> bool:
    """True when ``kind`` layers keep a window-bounded ring KV cache under
    ``cfg`` — decided by the config's layer-kind table (serve/paging.py's
    ``cache_kind``), not the kind string alone: recurrent kinds carry no KV
    at all and an attention kind is a ring only when the config gives it a
    window."""
    return cache_kind(cfg, kind) == "ring"


class ServeEngine:
    """Greedy batched generation for any registered arch."""

    def __init__(self, cfg, params, max_len: int = 4096):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(partial(lm.prefill, cfg=cfg))
        self._decode = jax.jit(partial(lm.decode_step, cfg=cfg))

    def generate(self, batch: Dict[str, jnp.ndarray], steps: int,
                 collect_logits: bool = False):
        """Returns generated tokens (B, steps) [+ final logits]."""
        logits, caches = self._prefill(self.params, batch=batch)
        caches = pad_caches(caches, self.max_len)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        outs = [tok]
        last_logits = logits
        for _ in range(steps - 1):
            tok, last_logits, caches = self._decode(self.params, tokens=tok,
                                                    caches=caches)
            outs.append(tok)
        tokens = jnp.concatenate(outs, axis=1)
        if collect_logits:
            return tokens, last_logits
        return tokens


def serve_step(params, cfg, tokens, caches):
    """The decode-shape dry-run entry point (one new token, big cache)."""
    return lm.decode_step(params, cfg, tokens, caches)


# --------------------------------------------------------------------------
# Continuous batching
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    """What one ``ContinuousServeEngine.serve`` call did."""
    tokens: Dict[int, np.ndarray]          # rid -> generated tokens
    states: List[RequestState]
    steps: int
    step_times: List[float]                # wall seconds per decode step
    kv_samples: List[Dict[str, int]]       # per-step PagePool.kv_bytes
    pool_stats: Dict[str, float]
    obs: Optional[Dict] = None             # obs.snapshot() when enabled

    @property
    def generated_tokens(self) -> int:
        return sum(len(s.tokens) for s in self.states)


@jax.jit
def _slot_write(big, one, slot):
    """Overwrite slot ``slot`` of the big rowwise caches with a padded
    B=1 prefill cache (per-row positions in both)."""
    g_big, t_big = big
    g_one, t_one = one
    if g_big is not None:
        g_big = jax.tree.map(
            lambda b, o: b.at[:, slot].set(o[:, 0].astype(b.dtype)),
            g_big, g_one)
    t_big = [jax.tree.map(lambda b, o: b.at[slot].set(o[0].astype(b.dtype)),
                          bb, oo)
             for bb, oo in zip(t_big, t_one)]
    return g_big, t_big


class ContinuousServeEngine:
    """Continuous-batching greedy decode with a paged, tiered KV store.

    Per step: admit waiting requests into free slots (exact-length B=1
    prefill, written into the slot row), run ONE batched decode step over
    all slots (per-row cache positions), evict finished requests, then
    compress pages that went cold into the ``kv_mode`` tier.
    """

    def __init__(self, cfg, params, max_len: int = 128, num_slots: int = 4,
                 page_size: int = 16, kv_mode: str = "raw",
                 kv_eb: float = 0.04, cold_after: int = 1,
                 kernel_backend: Optional[str] = None,
                 verify_guarantees: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.num_slots = num_slots
        self.pool = PagePool(cfg, num_slots, max_len, page_size,
                             kv_mode=kv_mode, eb=kv_eb,
                             cold_after=cold_after, backend=kernel_backend,
                             verify=verify_guarantees)
        self._prefill = jax.jit(partial(lm.prefill, cfg=cfg))
        self._decode = jax.jit(partial(lm.decode_step, cfg=cfg))

    def _init_caches(self):
        caches = lm.make_caches(self.cfg, self.num_slots, self.max_len)
        return lm.rowwise_caches(pad_caches(caches, self.max_len))

    def _admit(self, st: RequestState, caches):
        """Prefill one request and write it into its slot."""
        with obs.span("serve.prefill", rid=st.req.rid, slot=st.slot):
            logits, one = self._prefill(self.params, batch=st.req.inputs)
            st.tokens.append(int(jnp.argmax(logits[:, -1, :], axis=-1)[0]))
            one = lm.rowwise_caches(pad_caches(one, self.max_len))
            caches = _slot_write(caches, one, jnp.int32(st.slot))
        obs.counter_add("serve.admitted", 1)
        return caches

    def serve(self, requests: List[Request]) -> ServeReport:
        """Run every request to completion; returns tokens + step stats."""
        sched = Scheduler(self.num_slots)
        for r in requests:
            if r.prompt_len(self.cfg) + r.max_new_tokens - 1 > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {r.prompt_len(self.cfg)} + "
                    f"{r.max_new_tokens} new tokens exceeds max_len "
                    f"{self.max_len}")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.rid}: max_new_tokens < 1")
            sched.add(r)

        caches = self._init_caches()
        step = 0
        step_times: List[float] = []
        kv_samples: List[Dict[str, int]] = []
        while sched.has_work():
            with obs.span("serve.admit", step=step):
                for st in sched.admit(step,
                                      lambda r: r.prompt_len(self.cfg)):
                    caches = self._admit(st, caches)
            for st in sched.evict_finished(step):   # 1-token requests
                self.pool.release_slot(st.slot)
                obs.counter_add("serve.evicted", 1)
            if not sched.active:
                step += 1
                continue

            toks = np.zeros((self.num_slots, 1), np.int32)
            for slot, st in sched.active.items():
                toks[slot, 0] = st.tokens[-1]
            t0 = time.perf_counter()
            with obs.span("serve.decode_step", step=step,
                          active=len(sched.active)):
                nxt, _, caches = self._decode(self.params,
                                              tokens=jnp.asarray(toks),
                                              caches=caches)
                nxt = jax.block_until_ready(nxt)
            dt = time.perf_counter() - t0
            step_times.append(dt)
            obs.counter_add("serve.decode_steps", 1)
            obs.observe("serve.step_time_s", dt)
            nxt_host = np.asarray(nxt)
            for st in sched.active.values():
                st.tokens.append(int(nxt_host[st.slot, 0]))
            for st in sched.evict_finished(step):
                self.pool.release_slot(st.slot)
                obs.counter_add("serve.evicted", 1)

            cold = self.pool.cold_pages(sched.positions())
            with obs.span("serve.page_compress", step=step,
                          cold_pages=len(cold)):
                caches = self.pool.compress_pages(caches, cold)
            kv = self.pool.kv_bytes(sched.positions())
            kv_samples.append(kv)
            if obs.enabled():       # host bookkeeping ints; no device reads
                obs.gauge_set("serve.resident_bytes", kv["resident_bytes"])
                obs.gauge_set("serve.raw_equiv_bytes", kv["raw_equiv_bytes"])
                obs.gauge_set("serve.cold_pages", kv["cold_pages"])
            step += 1

        self._caches = caches                      # exposed for tests
        tokens = {st.req.rid: np.asarray(st.tokens, np.int32)
                  for st in sched.finished}
        return ServeReport(tokens=tokens, states=sched.finished, steps=step,
                           step_times=step_times, kv_samples=kv_samples,
                           pool_stats=dict(self.pool.stats),
                           obs=obs.snapshot() if obs.enabled() else None)
