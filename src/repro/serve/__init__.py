from repro.serve.engine import ServeEngine, serve_step, pad_caches

__all__ = ["ServeEngine", "serve_step", "pad_caches"]
