from repro.serve.engine import (ContinuousServeEngine, ServeEngine,
                                ServeReport, is_ring, pad_caches, serve_step)
from repro.serve.paging import KV_MODES, PagePool, cache_kind
from repro.serve.scheduler import Request, RequestState, Scheduler

__all__ = [
    "ServeEngine", "ContinuousServeEngine", "ServeReport", "serve_step",
    "pad_caches", "is_ring",
    "PagePool", "cache_kind", "KV_MODES",
    "Request", "RequestState", "Scheduler",
]
