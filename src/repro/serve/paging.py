"""Paged KV allocator + tiered error-bounded page compression.

The serve caches are the standard padded decode caches (every attention
layer at ``max_len`` slots, batch dim = request slots); this module carves
the (slot, seq) plane of the *full* (non-ring) KV layers into fixed-size
pages and runs the tier store on top:

    slot 0  | page 0 | page 1 | page 2 | ...     a page spans page_size
    slot 1  | page 0 | page 1 | ...              positions in EVERY full
    ...                                          KV layer (k and v)

Layer kinds route by :func:`cache_kind` (the config's layer-kind table):

    'global' attention  -> "full"       paged + compressible
    'local'  attention  -> "ring"       pass-through (window-bounded)
    'recurrent'/'rwkv'  -> "recurrent"  pass-through (O(1) state)

A page becomes COLD once every position in it is ``cold_after`` decode
steps old; cold pages are compressed in one batched SZp/TopoSZp call
(``kv_mode``), the stream becomes the page's durable resident copy, and
the cache region is overwritten with the stream's decompressed
reconstruction — decompression is deterministic, so the materialized view
is bit-identical to what an on-demand decompress of the stored stream
returns (``fetch_page`` reads the store directly and the tests assert
exactly that).  With ``kv_mode="toposzp"`` every decompressed page field
keeps the paper's guarantee: |err| <= 2*eb and zero false critical points
w.r.t. the original page's label map.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.bitpack import width_bucket
from repro.core.critical_points import classify
from repro.core.guarantees import violations
from repro.core.szp import (DEFAULT_BLOCK, szp_compress_batch,
                            szp_decompress_batch)
from repro.core.toposzp import (fields_as_pages, pages_as_fields,
                                toposzp_compress_batch,
                                toposzp_decompress_batch)
from repro.kernels import ops
from repro.models.attention import _window

KV_MODES = ("raw", "szp", "toposzp")


def cache_kind(cfg, kind: str) -> str:
    """Decode-state kind of a layer under ``cfg``: "full" (paged KV),
    "ring" (window-bounded KV, pass-through) or "recurrent" (O(1) state,
    pass-through)."""
    if kind in ("rwkv", "recurrent"):
        return "recurrent"
    if kind in ("global", "local"):
        return "ring" if _window(cfg, kind) is not None else "full"
    raise KeyError(f"unknown layer kind {kind!r}")


def _kv_layer_index(cfg) -> List[Tuple[str, int, int]]:
    """Enumerate the pageable (full-KV) layer arrays in cache order.

    Entries are ("g", pattern_idx, group_idx) for scanned-group layers and
    ("t", tail_idx, 0) for tail layers; each contributes a k and a v field
    per page.
    """
    groups, tail = cfg.pattern_layers()
    idx: List[Tuple[str, int, int]] = []
    if groups:
        for i, kind in enumerate(cfg.layer_pattern):
            if cache_kind(cfg, kind) == "full":
                for g in range(len(groups)):
                    idx.append(("g", i, g))
    for j, kind in enumerate(tail):
        if cache_kind(cfg, kind) == "full":
            idx.append(("t", j, 0))
    return idx


class PagePool:
    """Slot/page bookkeeping + the compressed tier store.

    The pool never owns the caches — the engine threads them through
    :meth:`compress_pages` — it owns the page state machine (FREE -> HOT
    -> COLD), the per-page streams, and the byte accounting.
    """

    def __init__(self, cfg, num_slots: int, max_len: int, page_size: int,
                 kv_mode: str = "raw", eb: float = 0.04, cold_after: int = 1,
                 backend: Optional[str] = None, block: int = DEFAULT_BLOCK,
                 verify: bool = False, max_pages_per_call: int = 8):
        if kv_mode not in KV_MODES:
            raise ValueError(f"kv_mode must be one of {KV_MODES}, "
                             f"got {kv_mode!r}")
        if max_len % page_size != 0:
            raise ValueError(f"max_len {max_len} not divisible by "
                             f"page_size {page_size}")
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        self.kv_mode = kv_mode
        self.eb = float(eb)
        self.cold_after = int(cold_after)
        self.block = block
        self.backend = ops.resolve_backend(backend)
        self.verify = verify
        self.max_pages_per_call = max_pages_per_call

        self.layers = _kv_layer_index(cfg)
        self.fields_per_page = 2 * len(self.layers)       # k and v each
        h, dh = cfg.num_kv_heads, cfg.head_dim      # == init_cache's shape
        self._page_shape = (page_size, h, dh)
        self._field_shape = (h * dh, page_size)           # channels x pos
        itemsize = jnp.dtype(cfg.activation_dtype).itemsize
        self.page_raw_bytes = (self.fields_per_page
                               * page_size * h * dh * itemsize)

        # (slot, page) -> {"call": int, "offset": int, "bytes": int}
        self._compressed: Dict[Tuple[int, int], Dict] = {}
        self._calls: Dict[int, Dict] = {}
        self._next_call = 0
        self.stats = {"pages_compressed": 0, "compress_calls": 0,
                      "max_abs_err": 0.0, "false_critical_points": 0,
                      "fields_verified": 0}

    # -- page state ---------------------------------------------------------

    def occupied_pages(self, next_pos: int) -> int:
        return min(-(-int(next_pos) // self.page_size), self.pages_per_slot)

    def cold_pages(self, positions: Dict[int, int]
                   ) -> List[Tuple[int, int]]:
        """Pages fully ``cold_after`` steps behind the write head and not
        yet compressed, per active slot."""
        out = []
        for slot, pos in positions.items():
            full = (int(pos) - self.cold_after) // self.page_size
            for p in range(min(full, self.pages_per_slot)):
                if (slot, p) not in self._compressed:
                    out.append((slot, p))
        return out

    def release_slot(self, slot: int) -> None:
        """Free a finished request's pages and drop their streams."""
        for key in [k for k in self._compressed if k[0] == slot]:
            info = self._compressed.pop(key)
            call = self._calls[info["call"]]
            call["refs"] -= 1
            if call["refs"] == 0:
                del self._calls[info["call"]]

    # -- byte accounting ----------------------------------------------------

    def kv_bytes(self, positions: Dict[int, int]) -> Dict[str, int]:
        """Resident paged-KV bytes: raw for HOT pages, stream bytes for
        COLD ones; ``raw_equiv`` is what the same occupancy costs with no
        tier store."""
        occupied = sum(self.occupied_pages(p) for p in positions.values())
        cold = [k for k in self._compressed if k[0] in positions]
        stream = sum(self._compressed[k]["bytes"] for k in cold)
        hot = occupied - len(cold)
        return {"occupied_pages": occupied,
                "cold_pages": len(cold),
                "hot_raw_bytes": hot * self.page_raw_bytes,
                "cold_stream_bytes": stream,
                "resident_bytes": hot * self.page_raw_bytes + stream,
                "raw_equiv_bytes": occupied * self.page_raw_bytes}

    # -- gather / scatter (page-indexed cache views) ------------------------

    def _layer_array(self, caches, which: str, i: int, g: int, name: str):
        gcaches, tcaches = caches
        c = gcaches[i] if which == "g" else tcaches[i]
        arr = getattr(c, name)
        return arr[g] if which == "g" else arr

    @functools.partial(jax.jit, static_argnums=(0,))
    def _gather(self, caches, slots, starts):
        """Page contents -> (M * fields_per_page, C, S_page) f32 fields."""
        ps = self.page_size

        def one(arr, b, lo):
            row = jax.lax.dynamic_index_in_dim(arr, b, 0, keepdims=False)
            return jax.lax.dynamic_slice_in_dim(row, lo, ps, axis=0)

        per_layer = []
        for which, i, g in self.layers:
            for name in ("k", "v"):
                arr = self._layer_array(caches, which, i, g, name)
                per_layer.append(jax.vmap(one, (None, 0, 0))(arr, slots,
                                                             starts))
        pages = jnp.stack(per_layer, axis=1)      # (M, L2, ps, H, Dh)
        m, l2 = pages.shape[0], pages.shape[1]
        return pages_as_fields(pages.reshape((m * l2,) + self._page_shape))

    @functools.partial(jax.jit, static_argnums=(0,))
    def _scatter(self, caches, fields, slots, starts):
        """Write decompressed fields back into the page regions."""
        m = slots.shape[0]
        pages = fields_as_pages(fields, self._page_shape)
        pages = pages.reshape((m, self.fields_per_page) + self._page_shape)
        gcaches, tcaches = caches
        gcaches = list(gcaches) if gcaches is not None else None
        tcaches = list(tcaches)
        li = 0
        for which, i, g in self.layers:
            c = gcaches[i] if which == "g" else tcaches[i]
            upd = {}
            for fi, name in enumerate(("k", "v")):
                arr = getattr(c, name)
                for j in range(m):
                    page = pages[j, li + fi].astype(arr.dtype)
                    at = ((g, slots[j], starts[j], 0, 0) if which == "g"
                          else (slots[j], starts[j], 0, 0))
                    arr = jax.lax.dynamic_update_slice(
                        arr, page[None, None] if which == "g" else page[None],
                        at)
                upd[name] = arr
            c = c._replace(**upd)
            if which == "g":
                gcaches[i] = c
            else:
                tcaches[i] = c
            li += 2
        gcaches = tuple(gcaches) if gcaches is not None else None
        return gcaches, tcaches

    # -- compression tier ---------------------------------------------------

    def _roundtrip(self, fields):
        """One batched device-resident compress + decompress.

        The compress runs the on-device bucket select (``resident=True``)
        and, when the fields aren't needed again for verification, donates
        the gathered buffer; nothing here syncs to the host — byte
        accounting comes back as device arrays for the per-sweep read.
        """
        donate = not self.verify
        if self.kv_mode == "szp":
            comp = szp_compress_batch(fields, self.eb, block=self.block,
                                      backend=self.backend, resident=True,
                                      donate=donate)
            dec = szp_decompress_batch(comp, self._field_shape, self.eb,
                                       block=self.block,
                                       backend=self.backend)
        else:
            comp = toposzp_compress_batch(fields, self.eb, block=self.block,
                                          backend=self.backend, resident=True,
                                          donate=donate)
            dec = toposzp_decompress_batch(comp, self._field_shape, self.eb,
                                           block=self.block,
                                           backend=self.backend)
        return comp, dec

    def _stream_widths_max(self, comp):
        """Device scalar: the stream's max block width (both sections for
        TopoSZp — the resident pack uses their shared bucket)."""
        if self.kv_mode == "szp":
            return comp.widths.astype(jnp.int32).max()
        return jnp.maximum(comp.szp.widths.astype(jnp.int32).max(),
                           comp.ranks.widths.astype(jnp.int32).max())

    def _trim_to_bucket(self, comp, wb: int):
        """Slice the worst-case resident payload capacity down to the
        measured WIDTH_BUCKETS capacity for the durable stored copy — a
        static device-side slice (6 possible shapes), no transfer; valid
        bytes always fit the bucket capacity."""
        def cap(parts):
            k = self.block - 1
            return parts.widths.shape[1] * ((k * wb + 7) // 8)

        def trim(parts):
            c = min(cap(parts), parts.payload.shape[1])
            return parts._replace(payload=parts.payload[:, :c])
        if self.kv_mode == "szp":
            return trim(comp)
        return comp._replace(szp=trim(comp.szp), ranks=trim(comp.ranks))

    def compress_pages(self, caches, pages: List[Tuple[int, int]]):
        """Compress ``pages`` into the tier store and materialize their
        reconstructions in the caches.  Returns the updated caches.

        The whole sweep stays on device; byte accounting (and the verify
        scalars) are read back in ONE blocking transfer at the end, not
        once per page or per chunk.
        """
        if self.kv_mode == "raw" or not pages:
            return caches
        pending = []
        for lo in range(0, len(pages), self.max_pages_per_call):
            caches, rec = self._compress_chunk(
                caches, pages[lo:lo + self.max_pages_per_call])
            pending.append(rec)
        self._finalize_sweep(pending)
        return caches

    def _compress_chunk(self, caches, chunk: List[Tuple[int, int]]):
        with obs.span("serve.compress_chunk", pages=len(chunk),
                      mode=self.kv_mode):
            return self._compress_chunk_inner(caches, chunk)

    def _compress_chunk_inner(self, caches, chunk: List[Tuple[int, int]]):
        m = len(chunk)
        # pad to a power-of-two bucket (duplicates of the last page) so the
        # compiled batch shapes come from a small static set
        bucket = 1
        while bucket < m:
            bucket *= 2
        padded = chunk + [chunk[-1]] * (bucket - m)
        slots = jnp.asarray([s for s, _ in padded], jnp.int32)
        starts = jnp.asarray([p * self.page_size for _, p in padded],
                             jnp.int32)
        fields = self._gather(caches, slots, starts)
        comp, dec = self._roundtrip(fields)
        l2 = self.fields_per_page
        acct = {"page_bytes": comp.nbytes.reshape(bucket, l2).sum(axis=1),
                "w_max": self._stream_widths_max(comp)}
        if self.verify:
            max_err, fp = _verify_fields(fields, dec)
            nf = m * l2
            acct["max_err"] = max_err[:nf].max()
            acct["fp"] = fp[:nf].sum()
        caches = self._scatter(caches, dec, slots, starts)

        cid = self._next_call
        self._next_call += 1
        self._calls[cid] = {"comp": comp, "pages": list(chunk), "refs": m}
        return caches, {"cid": cid, "chunk": chunk, "acct": acct}

    def _finalize_sweep(self, pending: List[Dict]) -> None:
        """ONE device->host read for the whole sweep's accounting, then
        host bookkeeping + trimming the stored streams to their measured
        bucket capacity.  This is the serve tier's designated sync point,
        so the obs counters fed here cost no extra transfers."""
        with obs.span("serve.finalize_sweep", chunks=len(pending)):
            accts = jax.device_get([rec["acct"] for rec in pending])
        sweep_bytes = 0
        for rec, acct in zip(pending, accts):
            cid, chunk = rec["cid"], rec["chunk"]
            wb = width_bucket(int(acct["w_max"]))
            self._calls[cid]["comp"] = self._trim_to_bucket(
                self._calls[cid]["comp"], wb)
            for j, key in enumerate(chunk):
                nb = int(acct["page_bytes"][j])
                sweep_bytes += nb
                self._compressed[key] = {
                    "call": cid, "offset": j, "bytes": nb}
            if self.verify:
                self.stats["max_abs_err"] = max(self.stats["max_abs_err"],
                                                float(acct["max_err"]))
                self.stats["false_critical_points"] += int(acct["fp"])
                self.stats["fields_verified"] += len(chunk) * self.fields_per_page
            self.stats["pages_compressed"] += len(chunk)
            self.stats["compress_calls"] += 1
            obs.counter_add("serve.pages_compressed", len(chunk))
            obs.counter_add("serve.compress_calls", 1)
            obs.counter_add(f"serve.page_bucket_{wb}", len(chunk))
        obs.counter_add("serve.cold_stream_bytes", sweep_bytes)

    def fetch_page(self, slot: int, page: int) -> jnp.ndarray:
        """Decompress one page from the tier store (on-demand read path):
        -> (fields_per_page, S_page, Hkv, Dh) f32, bit-identical to the
        reconstruction materialized in the caches at compress time."""
        info = self._compressed[(slot, page)]
        comp = self._calls[info["call"]]["comp"]
        if self.kv_mode == "szp":
            dec = szp_decompress_batch(comp, self._field_shape, self.eb,
                                       block=self.block,
                                       backend=self.backend)
        else:
            dec = toposzp_decompress_batch(comp, self._field_shape, self.eb,
                                           block=self.block,
                                           backend=self.backend)
        l2 = self.fields_per_page
        dec = dec[info["offset"] * l2:(info["offset"] + 1) * l2]
        return fields_as_pages(dec, self._page_shape)


@jax.jit
def _verify_fields(orig, dec):
    """Per-field max error + false-critical-point count (FP or FT) of the
    reconstruction w.r.t. the original field's label map."""
    def one(o, d):
        return jnp.abs(d - o).max(), violations(d, classify(o)).sum()
    errs, fps = jax.vmap(one)(orig, dec)
    return errs, fps
