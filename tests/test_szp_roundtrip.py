"""SZp end-to-end: error bound, code roundtrip exactness, serialization."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import io as cio
from repro.core.szp import (compress_codes, decompress_codes, szp_compress,
                            szp_decompress, szp_roundtrip)


@pytest.mark.parametrize("eb", [1e-2, 1e-3, 1e-4])
@pytest.mark.parametrize("shape", [(96, 128), (61, 77), (1, 257)])
def test_szp_error_bound(eb, shape, smooth_field):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    rec, parts = szp_roundtrip(x, eb)
    tol = eb + 4 * float(np.spacing(np.float32(float(jnp.abs(x).max()) + eb)))
    assert float(jnp.abs(rec - x).max()) <= tol
    assert int(parts.nbytes) > 0


def test_codes_lossless_roundtrip():
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(-2 ** 28, 2 ** 28, 4096, dtype=np.int64)
                        .astype(np.int32))
    parts = compress_codes(codes)
    out = decompress_codes(parts, 4096)
    assert bool(jnp.all(out == codes))


def test_smooth_field_compresses_well(smooth_field):
    rec, parts = szp_roundtrip(jnp.asarray(smooth_field), 1e-2)
    ratio = 4 * smooth_field.size / int(parts.nbytes)
    assert ratio > 3.0, f"smooth field should compress >3x, got {ratio}"


def test_serialize_roundtrip(smooth_field):
    f = jnp.asarray(smooth_field)
    eb = 1e-3
    parts = szp_compress(f, eb)
    blob = cio.serialize_szp(parts, f.shape, eb)
    parts2, shape, eb2, block = cio.deserialize_szp(blob)
    rec1 = szp_decompress(parts, tuple(f.shape), eb)
    rec2 = szp_decompress(parts2, shape, eb2, block=block)
    assert bool(jnp.all(rec1 == rec2))
    # true on-disk size within a header of the jit-side accounting
    assert abs(len(blob) - int(parts.nbytes)) <= 64


def test_rank_stream_bytes_matches_serialized(smooth_field):
    """The jit-side sparse-rank accounting equals the real byte size of the
    trimmed rank stream (serialize-time `_trim_rank_parts` slicing)."""
    from repro.core.io import _trim_rank_parts
    from repro.core.szp import DEFAULT_BLOCK
    from repro.core.toposzp import rank_stream_bytes, toposzp_compress

    f = jnp.asarray(smooth_field)
    eb = 1e-3
    comp = toposzp_compress(f, eb)
    n_cp = int(comp.n_cp)
    assert n_cp > 0, "fixture must contain critical points"
    trimmed = _trim_rank_parts(comp.ranks, n_cp, DEFAULT_BLOCK)
    blob = cio.serialize_szp(trimmed, f.shape, eb, DEFAULT_BLOCK)
    accounted = int(rank_stream_bytes(comp.n_cp, comp.ranks.payload_nbytes,
                                      DEFAULT_BLOCK))
    assert len(blob) == accounted, (len(blob), accounted)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1e-2, 1e-3]),
       st.integers(2, 9))
def test_property_roundtrip_bound(seed, eb, rows):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-5, 5, (rows, 33)).astype(np.float32))
    rec, _ = szp_roundtrip(x, eb)
    assert float(jnp.abs(rec - x).max()) <= eb * (1 + 1e-5)
