"""Shared fixtures.  NOTE: no XLA device-count forcing here — smoke tests
and benches must see the real single CPU device; multi-device tests spawn
subprocesses with their own XLA_FLAGS (see test_dryrun_small.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def smooth_field():
    rng = np.random.default_rng(0)
    ny, nx = 96, 128
    y, x = np.meshgrid(np.linspace(0, 4 * np.pi, ny),
                       np.linspace(0, 4 * np.pi, nx), indexing="ij")
    f = np.sin(x) * np.cos(y) + 0.1 * rng.standard_normal((ny, nx))
    return f.astype(np.float32)


@pytest.fixture(scope="session")
def noisy_field():
    rng = np.random.default_rng(1)
    return rng.standard_normal((64, 80)).astype(np.float32)


@pytest.fixture(scope="session")
def vortex():
    from repro.data.fields import vortex_field
    return vortex_field(128, 160, n_vortices=50, seed=3)
