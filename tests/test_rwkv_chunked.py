"""Chunked (matmul-form) WKV vs the sequential-scan oracle (§Perf opt)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm, registry
from repro.models.rwkv6 import _wkv_chunked, _wkv_step


@pytest.mark.parametrize("s_len", [16, 33, 64])
@pytest.mark.parametrize("seed", [0, 1])
def test_chunked_matches_scan(s_len, seed):
    rng = np.random.default_rng(seed)
    b, h, d = 2, 3, 8
    r, k, v = (jnp.asarray(rng.standard_normal((s_len, b, h, d))
                           .astype(np.float32)) for _ in range(3))
    # realistic decays: log w = -exp(-5 + noise) in (-0.05, 0)
    w_log = -np.exp(rng.uniform(-6, -4, (s_len, b, h, d))).astype(np.float32)
    w = jnp.exp(jnp.asarray(w_log))
    u = jnp.asarray(rng.standard_normal((h, d)).astype(np.float32) * 0.3)
    s0 = jnp.asarray(rng.standard_normal((b, h, d, d)).astype(np.float32))

    def body(s, inp):
        rt, kt, vt, wt = inp
        return _wkv_step(s, rt, kt, vt, wt, u)

    s_ref, out_ref = jax.lax.scan(body, s0, (r, k, v, w))
    s_chk, out_chk = _wkv_chunked(s0, r, k, v, jnp.asarray(w_log), u)

    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_model_end_to_end():
    """Full rwkv6 model: chunked impl matches scan impl loss + decode."""
    cfg_scan = registry.get_smoke_config("rwkv6_3b")
    cfg_chnk = cfg_scan.replace(rwkv_impl="chunked")
    params = lm.init_params(cfg_scan, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg_scan.vocab_size)
    l_scan = lm.loss_fn(params, cfg_scan, {"tokens": toks})
    l_chnk = lm.loss_fn(params, cfg_chnk, {"tokens": toks})
    assert abs(float(l_scan) - float(l_chnk)) < 5e-2, (l_scan, l_chnk)

    # grads flow through the chunked path
    g = jax.grad(lambda p: lm.loss_fn(p, cfg_chnk, {"tokens": toks}))(params)
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in jax.tree.leaves(g))
