"""Three-way backend parity for the production compression pipeline.

The contract of the kernels.ops dispatch (ISSUE 5 tentpole): compressed
streams are BYTE-identical across ``backend={"pallas"(=interpret off-TPU),
"interpret","jnp"}``, batched APIs equal per-field loops, and the guarded
MXU tri-matmul dequant falls back to the exact int32 path when codes can
reach the f32-inexact >= 2^24 range.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import io as cio
from repro.core import bitpack, false_cases_host, max_abs_error
from repro.core.szp import (_dequant_stage, compress_codes, decompress_codes,
                            szp_compress, szp_compress_batch, szp_decompress,
                            szp_decompress_batch)
from repro.core.toposzp import (batch_slice, toposzp_compress,
                                toposzp_compress_batch, toposzp_decompress,
                                toposzp_decompress_batch)
from repro.kernels import ops

BACKENDS = ("pallas", "interpret", "jnp")


def _random_field(seed, shape, rough=False):
    rng = np.random.default_rng(seed)
    f = rng.standard_normal(shape).astype(np.float32)
    if not rough:
        y, x = np.meshgrid(np.linspace(0, 5, shape[0]),
                           np.linspace(0, 5, shape[1]), indexing="ij")
        f = (np.sin(x) * np.cos(y) + 0.05 * f).astype(np.float32)
    return jnp.asarray(f)


@pytest.mark.parametrize("shape,eb", [((64, 96), 1e-3), ((33, 77), 1e-2),
                                      ((7, 130), 1e-4)])
def test_szp_streams_byte_identical(shape, eb):
    x = _random_field(shape[0], shape, rough=True)
    blobs = {be: cio.serialize_szp(szp_compress(x, eb, backend=be),
                                   shape, eb) for be in BACKENDS}
    assert blobs["pallas"] == blobs["interpret"] == blobs["jnp"]
    for be in BACKENDS:
        rec = szp_decompress(szp_compress(x, eb, backend=be), shape, eb,
                             backend=be)
        assert float(jnp.abs(rec - x).max()) <= eb * (1 + 1e-5)


@pytest.mark.parametrize("shape,eb", [((48, 64), 1e-2), ((61, 41), 1e-3)])
def test_toposzp_streams_byte_identical_and_guaranteed(shape, eb):
    f = _random_field(shape[1], shape)
    blobs = {}
    for be in BACKENDS:
        comp = toposzp_compress(f, eb, backend=be)
        blobs[be] = cio.serialize_toposzp(comp, shape, eb)
        rec = toposzp_decompress(comp, shape, eb, backend=be)
        fc = false_cases_host(f, rec)
        assert fc["FP"] == 0 and fc["FT"] == 0, (be, fc)
        assert float(max_abs_error(f, rec)) <= 2 * eb * (1 + 1e-5)
    assert blobs["pallas"] == blobs["interpret"] == blobs["jnp"]


def test_extrema_and_base_bitwise_across_backends():
    """Everything before the RBF estimate is bit-identical across backends
    (the Shepard estimate itself is allclose-only: separable vs direct
    summation order)."""
    from repro.core.stencils import apply_extrema_stencils
    from repro.core.critical_points import classify
    from repro.core.quantize import quantize_roundtrip
    from repro.core.relative_order import compute_ranks
    from repro.core.quantize import quantize
    f = _random_field(3, (50, 70))
    eb = 1e-2
    recon = quantize_roundtrip(f, eb)
    labels = classify(f)
    ranks = compute_ranks(f, labels, quantize(f, eb))
    outs = [apply_extrema_stencils(recon, labels, ranks, eb, backend=be)[0]
            for be in BACKENDS]
    assert jnp.array_equal(outs[0], outs[1])
    assert jnp.array_equal(outs[1], outs[2])
    # and the kernel-dispatched form matches the legacy jnp stencil math
    legacy, _ = apply_extrema_stencils(recon, labels, ranks, eb)
    assert jnp.array_equal(outs[2], legacy)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1e-2, 1e-3]),
       st.sampled_from([16, 32, 64]), st.integers(1, 9),
       st.sampled_from(["smooth", "rough", "quantized", "spiky"]))
def test_property_roundtrip_and_parity(seed, eb, block, rows, kind):
    """Hypothesis sweep over (shape, eb, block, width distribution):
    bound-respecting roundtrip + byte-identical streams on every draw."""
    rng = np.random.default_rng(seed)
    shape = (rows, int(rng.integers(17, 80)))
    x = rng.uniform(-4, 4, shape).astype(np.float32)
    if kind == "quantized":          # many zero-delta / constant blocks
        x = np.round(x)
    elif kind == "spiky":            # wide width distribution in one field
        x[rng.integers(0, rows), :] *= 1e4
    elif kind == "smooth":
        x = np.cumsum(x, axis=1) * 0.01
    x = jnp.asarray(x.astype(np.float32))
    # f32 representation error dominates eb at spiky magnitudes; same
    # spacing-aware tolerance as test_szp_roundtrip.test_szp_error_bound.
    tol = eb + 4 * float(np.spacing(np.float32(float(jnp.abs(x).max()) + eb)))
    blobs = {}
    for be in ("interpret", "jnp"):
        parts = szp_compress(x, eb, block=block, backend=be)
        blobs[be] = cio.serialize_szp(parts, shape, eb, block)
        rec = szp_decompress(parts, shape, eb, block=block, backend=be)
        assert float(jnp.abs(rec - x).max()) <= tol
    assert blobs["interpret"] == blobs["jnp"]


# --------------------------------------------------------------------------
# batched APIs == per-field loops
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("interpret", "jnp"))
def test_szp_batch_equals_loop(backend):
    rng = np.random.default_rng(0)
    shape = (40, 56)
    xs = jnp.asarray(rng.standard_normal((4,) + shape).astype(np.float32))
    eb = 1e-3
    bparts = szp_compress_batch(xs, eb, backend=backend)
    outs = szp_decompress_batch(bparts, shape, eb, backend=backend)
    for i in range(xs.shape[0]):
        parts = szp_compress(xs[i], eb, backend=backend)
        sliced = jax.tree_util.tree_map(lambda a: a[i], bparts)
        assert (cio.serialize_szp(sliced, shape, eb)
                == cio.serialize_szp(parts, shape, eb))
        rec = szp_decompress(parts, shape, eb, backend=backend)
        assert jnp.array_equal(outs[i], rec)


@pytest.mark.parametrize("backend", ("interpret", "jnp"))
def test_toposzp_batch_equals_loop(backend):
    shape = (36, 44)
    fields = jnp.stack([_random_field(s, shape, rough=(s % 2 == 0))
                        for s in range(3)])
    eb = 1e-2
    bcomp = toposzp_compress_batch(fields, eb, backend=backend)
    brec = toposzp_decompress_batch(bcomp, shape, eb, backend=backend)
    for i in range(3):
        comp = toposzp_compress(fields[i], eb, backend=backend)
        assert (cio.serialize_toposzp(batch_slice(bcomp, i), shape, eb)
                == cio.serialize_toposzp(comp, shape, eb))
        rec = toposzp_decompress(batch_slice(bcomp, i), shape, eb,
                                 backend=backend)
        assert jnp.array_equal(brec[i], rec)


def test_batch_rejects_wrong_rank():
    with pytest.raises(ValueError):
        toposzp_compress_batch(jnp.zeros((8, 8)), 1e-2)


# --------------------------------------------------------------------------
# the 2^24 tri-matmul guard (ISSUE 5 satellite: regression w/ huge codes)
# --------------------------------------------------------------------------

def test_dequant_guard_falls_back_past_2p24():
    """Codes with >= 2^24 deltas: the f32 tri-matmul cumsum is INEXACT
    (demonstrated by bypassing the guard), and the guarded decompress
    routes to the int32 path so all backends stay bit-identical."""
    k = 32
    step = (1 << 24) + 1                       # not f32-representable
    codes = jnp.asarray(np.arange(64, dtype=np.int64) * step % (1 << 30),
                        dtype=jnp.int32)
    parts = compress_codes(codes, block=k)
    assert int(np.asarray(parts.widths).max()) >= 24
    eb = 1.0
    n = int(codes.shape[0])
    # exact path == dequantized true codes
    want = (codes.astype(jnp.float32) * 2.0).astype(jnp.float32)
    got_guarded = szp_decompress(parts, (1, n), eb, block=k,
                                 backend="interpret").reshape(-1)
    assert jnp.array_equal(got_guarded, want)
    # bypassing the guard hits the f32-inexact tri-matmul: different bytes
    got_raw = _dequant_stage(parts, n, eb, k, "center", "interpret")
    assert not jnp.array_equal(got_raw, want), \
        "tri-matmul unexpectedly exact; the guard test lost its teeth"


def test_toposzp_huge_dynamic_range_still_guaranteed():
    """End-to-end roundtrip whose main-stream codes exceed 2^24 (guard
    engaged inside toposzp_decompress): bound + FP/FT still hold and the
    backends still agree bit-for-bit on the stream."""
    rng = np.random.default_rng(5)
    f = jnp.asarray(rng.uniform(-8, 8, (24, 40)).astype(np.float32))
    eb = 1e-8                                   # codes ~ 4e8 >> 2^24
    blobs = {}
    for be in ("interpret", "jnp"):
        comp = toposzp_compress(f, eb, backend=be)
        blobs[be] = cio.serialize_toposzp(comp, (24, 40), eb)
        rec = toposzp_decompress(comp, (24, 40), eb, backend=be)
        fc = false_cases_host(f, rec)
        assert fc["FP"] == 0 and fc["FT"] == 0
        assert float(max_abs_error(f, rec)) <= 2 * eb * (1 + 1e-4) + 1e-6
    assert blobs["interpret"] == blobs["jnp"]


def test_rank_stream_lossless_regardless_of_backend():
    """The rank metadata decode always takes the exact int path: huge rank
    codes roundtrip exactly (lossless contract of section 7)."""
    rng = np.random.default_rng(9)
    codes = jnp.asarray(
        rng.integers(-(2 ** 28), 2 ** 28, 512, dtype=np.int64)
        .astype(np.int32))
    parts = compress_codes(codes)
    assert bool(jnp.all(decompress_codes(parts, 512) == codes))


# --------------------------------------------------------------------------
# odd-shape tile rule (ISSUE 5 satellite: shared pad-to-tile fix)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b", [1, 5, 31, 100, 129, 257, 300])
@pytest.mark.parametrize("tb", [8, 256])
def test_odd_row_counts_match_oracle(b, tb):
    rng = np.random.default_rng(b * tb)
    k = 16
    eb = 1e-3
    xb = jnp.asarray(rng.standard_normal((b, k)).astype(np.float32))
    out_k = ops.szp_quant(xb, eb, backend="interpret", tb=tb)
    out_r = ops.szp_quant(xb, eb, backend="jnp")
    for a, r, name in zip(out_k, out_r, ["first", "mags", "signs", "widths"]):
        assert a.shape == r.shape, (name, a.shape, r.shape)
        assert jnp.array_equal(a, r), name
    first, mags, signs, widths = out_r
    rec_k = ops.szp_dequant(first, mags, signs, eb, backend="interpret",
                            tb=tb)
    rec_r = ops.szp_dequant(first, mags, signs, eb, backend="jnp")
    assert rec_k.shape == rec_r.shape
    np.testing.assert_allclose(np.asarray(rec_k), np.asarray(rec_r),
                               atol=1e-6)
    mw = bitpack.width_bucket(int(widths.max()))
    lp_k = ops.local_pack(mags, widths, max_width=mw, backend="interpret",
                          tb=tb)
    lp_r = ops.local_pack(mags, widths, max_width=mw, backend="jnp")
    assert jnp.array_equal(lp_k, lp_r)


def test_row_tile_rule():
    """One rule for every wrapper: tile = min(tb, ceil(b/8)*8)."""
    assert ops._row_tile(1, 256) == 8
    assert ops._row_tile(100, 256) == 104
    assert ops._row_tile(129, 256) == 136
    assert ops._row_tile(300, 256) == 256
    assert ops._row_tile(256, 256) == 256


def test_resolve_backend(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    assert ops.resolve_backend("interpret") == "interpret"
    assert ops.resolve_backend("jnp") == "jnp"
    # off-TPU, "pallas" downgrades to interpret; None resolves to jnp
    if jax.default_backend() != "tpu":
        assert ops.resolve_backend("pallas") == "interpret"
        assert ops.resolve_backend(None) == "jnp"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "jnp")
    assert ops.resolve_backend(None) == "jnp"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "bogus")
    with pytest.raises(ValueError):
        ops.resolve_backend(None)
    with pytest.raises(ValueError):
        ops.resolve_backend("bogus")
