"""Manual-EP MoE (shard_map) vs the GSPMD einsum path (§Perf opt)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_shard_map_matches_einsum_multi_device():
    py = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import lm, registry, set_active_mesh
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        cfg_e = registry.get_smoke_config('olmoe_1b_7b').replace(
            capacity_factor=8.0)
        cfg_s = cfg_e.replace(moe_impl='shard_map')
        params = lm.init_params(cfg_e, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg_e.vocab_size)
        set_active_mesh(mesh)
        with mesh:
            l_e = jax.jit(lambda p: lm.loss_fn(p, cfg_e,
                                               {'tokens': toks}))(params)
            l_s = jax.jit(lambda p: lm.loss_fn(p, cfg_s,
                                               {'tokens': toks}))(params)
        assert abs(float(l_e) - float(l_s)) < 2e-2, (float(l_e), float(l_s))
        print('MOE-EP-OK', float(l_e), float(l_s))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MOE-EP-OK" in out.stdout
