"""Gradient-compression collective: homomorphism, error bound, feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import quantize_dequantize_sum


@pytest.mark.parametrize("rel_eb", [1e-3, 1e-4])
@pytest.mark.parametrize("n", [2, 8, 32])
def test_homomorphic_sum(rel_eb, n):
    """sum(dequant(codes)) == dequant(sum(codes)) within n*eb (hZCCL)."""
    rng = np.random.default_rng(n)
    xs = jnp.asarray(rng.standard_normal((n, 4096)).astype(np.float32))
    homo, direct = quantize_dequantize_sum(xs, rel_eb=rel_eb)
    eb = rel_eb * float(jnp.abs(xs).max())
    assert float(jnp.abs(homo - direct).max()) <= n * eb * (1 + 1e-5)


def test_error_feedback_unbiased():
    """Error feedback drives the cumulative compression error to ~0."""
    from repro.core.quantize import quantize, dequantize
    rng = np.random.default_rng(0)
    g = rng.standard_normal(1000).astype(np.float32)
    eb = 1e-2
    err = np.zeros_like(g)
    acc_comp, acc_true = np.zeros_like(g), np.zeros_like(g)
    for step in range(50):
        gs = g * (1 + 0.1 * np.sin(step))
        ge = gs + err
        q = np.round(ge / (2 * eb))
        deq = q * 2 * eb
        err = ge - deq
        acc_comp += deq
        acc_true += gs
    # accumulated compressed sum tracks the true sum within one step's eb
    assert np.abs(acc_comp - acc_true).max() <= 2 * eb + 1e-6


def test_compressed_code_width_small():
    """Typical gradients need ~8-12 bits/value, i.e. 3-4x over bf16 wire."""
    from repro.dist.collectives import code_bits
    rng = np.random.default_rng(1)
    g = jnp.asarray((rng.standard_normal(65536) * 1e-3).astype(np.float32))
    w = int(code_bits(g, rel_eb=1e-3))
    assert w <= 12, w
