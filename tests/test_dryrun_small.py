"""Multi-device integration tests (subprocess: forced host device count).

Covers: small-mesh dry-run lower+compile for representative cells (incl. a
multi-pod mesh), sharding-rule sanity, and the elastic-mesh rebuild path.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _run(py: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["REPRO_XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["XLA_FLAGS"] = env["REPRO_XLA_FLAGS"]
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(py)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_dryrun_cells_small_mesh():
    out = _run("""
        import repro.configs.base as cb
        from repro.launch import dryrun
        from repro.launch.mesh import make_test_mesh
        from repro.models import registry
        mesh = make_test_mesh(2, 2)
        cb.SHAPES['train_4k'] = cb.ShapeConfig('train_4k', 128, 4, 'train')
        cb.SHAPES['decode_32k'] = cb.ShapeConfig('decode_32k', 128, 4, 'decode')
        for arch in ['gemma2_2b', 'olmoe_1b_7b', 'rwkv6_3b']:
            cfg = registry.get_smoke_config(arch)
            for shape in ['train_4k', 'decode_32k']:
                rec = dryrun.run_cell(arch, shape, False, mesh=mesh, cfg=cfg,
                                      save=False, costing=True)
                assert rec['cost'].get('flops', 0) > 0
                assert rec['costing'] and 'cost' in rec['costing']
        print('SMALL-MESH-OK')
    """)
    assert "SMALL-MESH-OK" in out


@pytest.mark.slow
def test_dryrun_multipod_small():
    out = _run("""
        import repro.configs.base as cb
        from repro.launch import dryrun
        from repro.launch.mesh import make_test_mesh
        from repro.models import registry
        mesh = make_test_mesh(2, 2, multi_pod=True)   # (2,2,2) = 8 devices
        cb.SHAPES['train_4k'] = cb.ShapeConfig('train_4k', 128, 4, 'train')
        cfg = registry.get_smoke_config('minicpm_2b')
        rec = dryrun.run_cell('minicpm_2b', 'train_4k', True, mesh=mesh,
                              cfg=cfg, save=False, costing=False)
        assert rec['mesh'] == 'multi'
        print('MULTIPOD-OK')
    """)
    assert "MULTIPOD-OK" in out


@pytest.mark.slow
def test_elastic_mesh_rebuild():
    out = _run("""
        import jax
        from repro.dist.elastic import rebuild_mesh, largest_mesh_shape
        devs = jax.devices()
        m1 = rebuild_mesh(devs, model_parallel=2)
        assert dict(zip(m1.axis_names, m1.devices.shape)) == {'data': 4, 'model': 2}
        # lose 3 devices -> mesh shrinks the data axis
        m2 = rebuild_mesh(devs[:5], model_parallel=2)
        assert m2.devices.size <= 5 and m2.devices.size >= 4
        assert largest_mesh_shape(7, 4) == (7, 1)
        print('ELASTIC-OK')
    """)
    assert "ELASTIC-OK" in out


def test_sharding_rules_cover_params():
    out = _run("""
        import jax
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.models import lm, registry
        mesh = make_test_mesh(2, 4)
        for arch in registry.ARCH_IDS:
            # production-representative dims (fsdp replicates tiny tensors)
            cfg = registry.get_smoke_config(arch).replace(
                d_model=512, d_ff=1024, num_heads=8, num_kv_heads=4,
                head_dim=64, vocab_size=2048, rnn_width=512,
                rwkv_head_dim=64)
            sds = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
            sh = shd.param_shardings(sds, cfg, mesh)
            total = sharded = 0
            import numpy as np
            for s, leaf in zip(jax.tree.leaves(sh), jax.tree.leaves(sds)):
                n = int(np.prod(leaf.shape)) if leaf.shape else 1
                total += n
                if any(x is not None for x in s.spec):
                    sharded += n
            frac = sharded / total
            assert frac > 0.5, (arch, frac)
        print('RULES-OK')
    """, devices=8)
    assert "RULES-OK" in out
