"""End-to-end behaviour tests for the TopoSZp system."""
import jax.numpy as jnp
import numpy as np

from repro.core import (false_cases_host, max_abs_error, szp_roundtrip,
                        toposzp_roundtrip)
from repro.core import io as cio
from repro.core.toposzp import toposzp_compress, toposzp_decompress
from repro.data.fields import make_dataset


def test_end_to_end_cesm_like_pipeline():
    """Compress a LAND-sized CESM-like field end to end through the real
    byte format, verify the paper's three claims: bound, FP=FT=0, FN win."""
    fields = make_dataset("LAND", n_fields=2, seed=1)
    eb = 1e-3
    for f in fields:
        fj = jnp.asarray(f)
        comp = toposzp_compress(fj, eb)
        blob = cio.serialize_toposzp(comp, f.shape, eb)       # real bytes
        comp2, shape, eb2, block = cio.deserialize_toposzp(blob)
        rec = toposzp_decompress(comp2, shape, eb2, block=block)

        assert float(max_abs_error(fj, rec)) <= 2 * eb * (1 + 1e-5)
        fc = false_cases_host(fj, rec)
        assert fc["FP"] == 0 and fc["FT"] == 0

        rec_szp, _ = szp_roundtrip(fj, eb)
        fn_szp = false_cases_host(fj, rec_szp)["FN"]
        if fn_szp > 10:
            assert fc["FN"] < fn_szp

        ratio = 4 * f.size / len(blob)
        assert ratio > 1.2, f"ratio collapsed: {ratio}"


def test_decompression_is_deterministic():
    f = jnp.asarray(make_dataset("ICE", n_fields=1, seed=3)[0])
    eb = 1e-3
    r1, c1 = toposzp_roundtrip(f, eb)
    r2, c2 = toposzp_roundtrip(f, eb)
    assert bool(jnp.all(r1 == r2))
    assert int(c1.nbytes) == int(c2.nbytes)
