"""dist.collectives coverage: homomorphic-sum error bounds across dtypes and
shapes (property), elastic-mesh policy, and an 8-fake-device end-to-end
compressed-DP training run (subprocess, same pattern as
tests/test_moe_shard_map.py)."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.dist.collectives import (code_bits, protect_k,
                                    quantize_dequantize_sum, sidecar_bits,
                                    topk_rank_preservation,
                                    topo_compressed_psum_tree,
                                    topo_quantize_dequantize_sum)
from repro.dist.elastic import largest_mesh_shape

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bound_ok(xs: np.ndarray, rel_eb: float) -> None:
    homo, direct = quantize_dequantize_sum(jnp.asarray(xs), rel_eb=rel_eb)
    n = xs.shape[0]
    eb = rel_eb * float(np.abs(xs.astype(np.float32)).max())
    err = float(jnp.abs(homo - direct).max())
    assert err <= n * eb * (1 + 1e-5) + 1e-30, (err, n * eb)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 64), (5, 17), (8, 256), (3, 4, 33)])
def test_homomorphic_bound_dtypes_shapes(dtype, shape):
    """|homo - direct| <= n * rel_eb * max|x| for every member dtype/shape
    (the sum-of-per-member-eb bound; quantization runs in f32)."""
    rng = np.random.default_rng([len(shape), shape[0], shape[-1]])
    xs = np.asarray(jnp.asarray(rng.standard_normal(shape)).astype(dtype))
    _bound_ok(xs, 1e-3)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1e-2, 1e-3, 1e-4]),
       st.integers(2, 16))
def test_property_homomorphic_bound(seed, rel_eb, n):
    rng = np.random.default_rng(seed)
    scale = 10.0 ** rng.integers(-4, 3)
    xs = (rng.standard_normal((n, 257)) * scale).astype(np.float32)
    _bound_ok(xs, rel_eb)


def test_all_zero_members_safe():
    """Zero gradients must not divide by a zero error bound."""
    homo, direct = quantize_dequantize_sum(jnp.zeros((4, 32)), rel_eb=1e-3)
    assert float(jnp.abs(homo).max()) == 0.0
    assert float(jnp.abs(direct).max()) == 0.0


def test_overflow_widened_at_tiny_rel_eb():
    """Regression: at rel_eb=1e-9 per-member codes are ~5e8 so an 8-member
    int32 code sum reaches 4e9 and silently wraps (the pre-fix path
    returned ~-0.29 here); the widened hi/lo accumulation recovers the
    true sum."""
    xs = jnp.full((8, 64), 0.5, jnp.float32)
    homo, direct = quantize_dequantize_sum(xs, rel_eb=1e-9)
    assert float(jnp.abs(direct - 4.0).max()) == 0.0
    assert float(jnp.abs(homo - 4.0).max()) < 1e-3, float(homo[0])


def test_overflow_widening_keeps_moderate_path_bitwise():
    """Widening must only engage when n * max_code can overflow: at
    ordinary rel_eb the raw int32 sum is still used (bit-identical)."""
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((8, 512)).astype(np.float32))
    homo, _ = quantize_dequantize_sum(xs, rel_eb=1e-3)
    from repro.core.quantize import dequantize, quantize
    from repro.dist.collectives import _leaf_eb
    eb = _leaf_eb(xs, 1e-3)
    ref = dequantize(quantize(xs, eb).sum(axis=0), eb)
    assert np.array_equal(np.asarray(homo), np.asarray(ref))


def test_rel_eb_too_small_raises():
    """Codes that overflow int32 in quantize() itself fail loudly."""
    xs = jnp.ones((2, 8), jnp.float32)
    with pytest.raises(ValueError, match="too small"):
        quantize_dequantize_sum(xs, rel_eb=1e-11)


def test_topo_sum_also_widened_at_tiny_rel_eb():
    """The topo variant's body sum takes the same widened path (it
    wrapped to ~-0.29 pre-fix, like the plain sum)."""
    xs = jnp.full((8, 64), 0.5, jnp.float32)
    topo, direct, prot = topo_quantize_dequantize_sum(xs, rel_eb=1e-9,
                                                      topo_frac=1e-2)
    body = np.delete(np.asarray(topo), np.asarray(prot))
    assert float(np.abs(body - 4.0).max()) < 1e-3, body[:4]
    assert np.array_equal(np.asarray(topo)[np.asarray(prot)],
                          np.asarray(direct)[np.asarray(prot)])
    with pytest.raises(ValueError, match="too small"):
        topo_quantize_dequantize_sum(xs, rel_eb=1e-11, topo_frac=1e-2)


def test_widening_member_limit_raises():
    """Past 2**15 members the lo sums would wrap int32 too: the widened
    path must refuse rather than reintroduce the silent wrap."""
    from repro.dist.collectives import _MAX_WIDEN_MEMBERS, _split_hi_lo
    q = jnp.ones((4,), jnp.int32)
    _split_hi_lo(q, _MAX_WIDEN_MEMBERS)          # boundary still exact
    with pytest.raises(ValueError, match="members"):
        _split_hi_lo(q, _MAX_WIDEN_MEMBERS + 1)


def test_rank_preservation_clamps_k():
    """Tree-level k larger than a small leaf must clamp, not crash."""
    d = jnp.asarray(np.array([5.0, 4.0, 3.0], np.float32))
    assert topk_rank_preservation(d, d, 64) == 1.0
    assert topk_rank_preservation(d, d, 0) == 1.0
    assert topk_rank_preservation(d, d, -3) == 1.0
    swapped = jnp.asarray(np.array([4.0, 5.0, 3.0], np.float32))
    assert topk_rank_preservation(d, swapped, 100) == pytest.approx(1 / 3)


def test_unknown_wire_format_raises():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.dist.collectives import compressed_psum_tree
    from repro.dist.compat import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="wire_format"):
        jax.jit(shard_map(
            lambda x: compressed_psum_tree({"g": x.reshape(-1)}, "data",
                                           wire_format="gzip")[0]["g"],
            mesh=mesh, in_specs=P("data"), out_specs=P(),
            check_vma=False))(g.reshape(1, -1))


def test_code_bits_monotone_in_eb():
    rng = np.random.default_rng(0)
    g = jnp.asarray((rng.standard_normal(4096) * 1e-3).astype(np.float32))
    widths = [int(code_bits(g, eb)) for eb in (1e-2, 1e-3, 1e-4)]
    assert widths == sorted(widths), widths
    assert all(1 <= w <= 32 for w in widths)


# --------------------------------------------------------------------------
# Topology-aware collective: exact protected tail + bounded body
# --------------------------------------------------------------------------

def _topo_ok(xs: np.ndarray, rel_eb: float, topo_frac: float) -> None:
    """Protected entries bit-exact; body within the n * eb bound."""
    topo, direct, protected = topo_quantize_dequantize_sum(
        jnp.asarray(xs), rel_eb=rel_eb, topo_frac=topo_frac)
    topo, direct = np.asarray(topo), np.asarray(direct)
    prot = np.asarray(protected)
    n = xs.shape[0]
    k = protect_k(xs[0].size, topo_frac)
    assert prot.shape == (n * k,)
    # (b) exact values — hence preserved rank order — for protected entries
    assert np.array_equal(topo.reshape(-1)[prot], direct.reshape(-1)[prot])
    # (a) homomorphic bound on the quantized body (protected entries have
    # zero error, so the global bound still holds elementwise)
    eb = rel_eb * float(np.abs(xs.astype(np.float32)).max())
    err = float(np.abs(topo - direct).max())
    assert err <= n * eb * (1 + 1e-5) + 1e-30, (err, n * eb)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, jnp.bfloat16])
@pytest.mark.parametrize("n", [1, 2, 5, 8])
def test_topo_protected_exact_dtypes_members(dtype, n):
    rng = np.random.default_rng(n)
    xs = rng.standard_normal((n, 999)) * 1e-3
    xs[:, rng.integers(0, 999, 8)] *= 100.0      # shared outlier tail
    xs = np.asarray(jnp.asarray(xs).astype(dtype))
    _topo_ok(xs, rel_eb=1e-3, topo_frac=1e-2)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1e-2, 1e-3]),
       st.integers(2, 16), st.sampled_from([1e-3, 1e-2, 0.1]))
def test_property_topo_exact_and_bounded(seed, rel_eb, n, topo_frac):
    rng = np.random.default_rng(seed)
    scale = 10.0 ** rng.integers(-4, 3)
    xs = (rng.standard_normal((n, 257)) * scale).astype(np.float32)
    _topo_ok(xs, rel_eb, topo_frac)


def test_protect_k_sizing():
    assert protect_k(1000, 0.0) == 0
    assert protect_k(1000, -1.0) == 0
    assert protect_k(0, 1e-3) == 0           # empty leaf: nothing to pin
    assert protect_k(1, 1e-3) == 1           # every leaf keeps its peak
    assert protect_k(1000, 1e-3) == 1
    assert protect_k(10**6, 1e-3) == 1000
    assert protect_k(10, 1.0) == 10          # never more than the leaf
    assert protect_k(10, 5.0) == 10


def test_sidecar_bits_accounting():
    # k=32 indices sent + 8*32 gathered fp32 values psum'd, 32 bits each
    assert sidecar_bits(32_000, 1e-3, n_members=8) == 32 * 32 + 8 * 32 * 32
    assert sidecar_bits(100, 0.0, n_members=8) == 0
    # sub-5%-overhead claim at topo_frac=1e-3 for a 10-bit body, n=8
    size = 1 << 20
    overhead = sidecar_bits(size, 1e-3, 8) / (10 * size)
    assert overhead < 0.05, overhead


def test_topo_wire_bits_is_body_plus_sidecar():
    from repro.dist.collectives import topo_wire_bits
    rng = np.random.default_rng(7)
    g = jnp.asarray((rng.standard_normal(4096) * 1e-3).astype(np.float32))
    total = topo_wire_bits(g, 1e-3, 1e-3, n_members=8)
    body = int(code_bits(g, 1e-3)) * g.size
    assert total == body + sidecar_bits(g.size, 1e-3, 8)
    assert topo_wire_bits(g, 1e-3, 0.0, n_members=8) == body


def test_topo_frac_zero_matches_plain():
    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.standard_normal((4, 512)).astype(np.float32))
    topo, direct, prot = topo_quantize_dequantize_sum(xs, 1e-3, 0.0)
    plain, direct2 = quantize_dequantize_sum(xs, 1e-3)
    assert prot.size == 0
    assert np.array_equal(np.asarray(topo), np.asarray(plain))
    assert np.array_equal(np.asarray(direct), np.asarray(direct2))


def test_rank_preservation_metric():
    direct = jnp.asarray(np.array([5.0, 4.0, 3.0, 2.0, 1.0], np.float32))
    assert topk_rank_preservation(direct, direct, 4) == 1.0
    swapped = jnp.asarray(np.array([4.0, 5.0, 3.0, 2.0, 1.0], np.float32))
    assert topk_rank_preservation(direct, swapped, 4) == 0.5


def test_topo_frac_requires_grad_compress():
    """A topo knob without the compressed collective must fail loudly,
    not silently run the uncompressed baseline."""
    from repro.models import registry
    from repro.optim import adamw, constant
    from repro.train import make_train_step

    cfg = registry.get_smoke_config("gemma2_2b")
    opt = adamw(constant(1e-3))
    with pytest.raises(ValueError, match="grad_compress"):
        make_train_step(cfg, opt, topo_frac=1e-3)
    with pytest.raises(ValueError, match="grad_compress"):
        make_train_step(cfg.replace(grad_topo_frac=1e-3), opt)
    # explicit 0 overrides the config knob -> plain baseline is fine
    make_train_step(cfg.replace(grad_topo_frac=1e-3), opt, topo_frac=0.0)


def test_psum_tree_empty_leaf():
    """Zero-size leaves (degenerate configs) must not crash either path."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.dist.collectives import compressed_psum_tree
    from repro.dist.compat import shard_map

    tree = {"g": jnp.zeros((0,), jnp.float32),
            "h": jnp.ones((8,), jnp.float32)}
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))

    def run(fn, **kw):
        def f(_):
            gbar, new_e = fn(tree, "data", rel_eb=1e-3, **kw)
            return gbar["h"], gbar["g"], new_e["g"]
        return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                 out_specs=(P(), P(), P()),
                                 check_vma=False))(jnp.zeros((1,)))

    for fn, kw in ((topo_compressed_psum_tree, {"topo_frac": 1e-3}),
                   (compressed_psum_tree, {})):
        h, g0, e0 = run(fn, **kw)
        assert np.array_equal(np.asarray(h), np.ones(8, np.float32))
        assert g0.shape == (0,) and e0.shape == (0,)


def test_topo_psum_tree_single_device():
    """Full shard_map path on one device: protected entries come back as
    their exact fp32 inputs and the error feedback is zeroed there."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.dist.compat import shard_map

    rng = np.random.default_rng(0)
    g = (rng.standard_normal(4096) * 1e-3).astype(np.float32)
    g[:16] *= 100.0
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    topo_frac = 1e-2

    def f(gs):
        gl = gs.reshape(-1)
        gbar, new_e = topo_compressed_psum_tree(
            {"g": gl}, "data", rel_eb=1e-3, topo_frac=topo_frac,
            err={"g": jnp.zeros_like(gl)})
        return gbar["g"], new_e["g"]

    gbar, new_e = jax.jit(shard_map(
        f, mesh=mesh, in_specs=P("data"), out_specs=(P(), P("data")),
        check_vma=False))(g.reshape(1, -1))
    k = protect_k(g.size, topo_frac)
    idx = np.argsort(-np.abs(g))[:k]
    assert np.array_equal(np.asarray(gbar)[idx], g[idx])
    assert float(np.abs(np.asarray(new_e).reshape(-1)[idx]).max()) == 0.0
    # unprotected body still eb-bounded (n=1)
    eb = 1e-3 * float(np.abs(g).max())
    assert float(np.abs(np.asarray(gbar) - g).max()) <= eb * (1 + 1e-5)


def test_largest_mesh_shape_policy():
    """Maximize devices used; break ties toward more model parallelism."""
    assert largest_mesh_shape(8, 2) == (4, 2)
    assert largest_mesh_shape(8, 4) == (2, 4)
    assert largest_mesh_shape(7, 4) == (7, 1)
    assert largest_mesh_shape(5, 2) == (5, 1)
    assert largest_mesh_shape(6, 2) == (3, 2)
    assert largest_mesh_shape(1, 8) == (1, 1)


@pytest.mark.slow
def test_compressed_psum_trains_multi_device():
    """compressed_psum_tree drives train/loop.py for 2 steps on a (4 data,
    2 model) mesh of 8 fake devices without NaNs."""
    py = textwrap.dedent("""
        import jax, numpy as np
        from repro.data import token_batches
        from repro.dist.elastic import rebuild_mesh
        from repro.models import lm, registry
        from repro.optim import adamw, constant
        from repro.train import init_state, make_train_step, train_loop

        cfg = registry.get_smoke_config('gemma2_2b')
        mesh = rebuild_mesh(jax.devices(), model_parallel=2)
        assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \\
            {'data': 4, 'model': 2}
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(constant(1e-3))
        state = init_state(params, opt, grad_compress=True)
        step = make_train_step(cfg, opt, mesh=mesh, grad_compress=True,
                               rel_eb=1e-3)
        data = token_batches(cfg, 8, 32, seed=0)
        state, rep = train_loop(state, step, data, num_steps=2,
                                log=lambda *_: None)
        assert rep.steps_run == 2, rep.steps_run
        assert all(np.isfinite(l) for l in rep.losses), rep.losses
        for leaf in jax.tree.leaves(state.params):
            assert bool(jax.numpy.all(jax.numpy.isfinite(
                leaf.astype(jax.numpy.float32))))
        print('COMPRESSED-DP-OK', [round(l, 4) for l in rep.losses])
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "COMPRESSED-DP-OK" in out.stdout


@pytest.mark.slow
def test_topo_psum_exact_multi_device():
    """topo_compressed_psum_tree on 8 fake devices: every protected union
    entry equals the direct psum mean bit-exactly (same reduction order as
    the reference psum of the raw values), and the error feedback is
    zeroed at protected entries on every member."""
    py = textwrap.dedent("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.collectives import protect_k, topo_compressed_psum_tree
        from repro.dist.compat import shard_map

        n, size, topo_frac = 8, 4096, 1e-2
        rng = np.random.default_rng(0)
        x = (rng.standard_normal((n, size)) * 1e-3).astype(np.float32)
        x[:, :32] *= 100.0
        mesh = Mesh(np.array(jax.devices()[:n]), ('data',))

        def f(xs):
            gl = xs.reshape(-1)
            gbar, new_e = topo_compressed_psum_tree(
                {'g': gl}, 'data', rel_eb=1e-3, topo_frac=topo_frac,
                err={'g': jnp.zeros_like(gl)})
            return gbar['g'], new_e['g']

        def ref(xs):
            return jax.lax.psum(xs.reshape(-1), 'data') / n

        sm = lambda fn, outs: jax.jit(shard_map(
            fn, mesh=mesh, in_specs=P('data'), out_specs=outs,
            check_vma=False))
        gbar, new_e = sm(f, (P(), P('data')))(jnp.asarray(x))
        exact_mean = np.asarray(sm(ref, P())(jnp.asarray(x)))

        k = protect_k(size, topo_frac)
        union = np.unique(np.argsort(-np.abs(x), axis=1)[:, :k])
        gbar = np.asarray(gbar)
        assert np.array_equal(gbar[union], exact_mean[union]), \\
            np.abs(gbar[union] - exact_mean[union]).max()
        err = np.asarray(new_e).reshape(n, size)
        assert float(np.abs(err[:, union]).max()) == 0.0
        eb = 1e-3 * float(np.abs(x).max())
        assert float(np.abs(gbar - x.mean(0)).max()) <= eb * (1 + 1e-5)
        print('TOPO-PSUM-EXACT-OK', k, union.size)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "TOPO-PSUM-EXACT-OK" in out.stdout
