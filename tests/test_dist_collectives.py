"""dist.collectives coverage: homomorphic-sum error bounds across dtypes and
shapes (property), elastic-mesh policy, and an 8-fake-device end-to-end
compressed-DP training run (subprocess, same pattern as
tests/test_moe_shard_map.py)."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.dist.collectives import code_bits, quantize_dequantize_sum
from repro.dist.elastic import largest_mesh_shape

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bound_ok(xs: np.ndarray, rel_eb: float) -> None:
    homo, direct = quantize_dequantize_sum(jnp.asarray(xs), rel_eb=rel_eb)
    n = xs.shape[0]
    eb = rel_eb * float(np.abs(xs.astype(np.float32)).max())
    err = float(jnp.abs(homo - direct).max())
    assert err <= n * eb * (1 + 1e-5) + 1e-30, (err, n * eb)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 64), (5, 17), (8, 256), (3, 4, 33)])
def test_homomorphic_bound_dtypes_shapes(dtype, shape):
    """|homo - direct| <= n * rel_eb * max|x| for every member dtype/shape
    (the sum-of-per-member-eb bound; quantization runs in f32)."""
    rng = np.random.default_rng([len(shape), shape[0], shape[-1]])
    xs = np.asarray(jnp.asarray(rng.standard_normal(shape)).astype(dtype))
    _bound_ok(xs, 1e-3)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1e-2, 1e-3, 1e-4]),
       st.integers(2, 16))
def test_property_homomorphic_bound(seed, rel_eb, n):
    rng = np.random.default_rng(seed)
    scale = 10.0 ** rng.integers(-4, 3)
    xs = (rng.standard_normal((n, 257)) * scale).astype(np.float32)
    _bound_ok(xs, rel_eb)


def test_all_zero_members_safe():
    """Zero gradients must not divide by a zero error bound."""
    homo, direct = quantize_dequantize_sum(jnp.zeros((4, 32)), rel_eb=1e-3)
    assert float(jnp.abs(homo).max()) == 0.0
    assert float(jnp.abs(direct).max()) == 0.0


def test_code_bits_monotone_in_eb():
    rng = np.random.default_rng(0)
    g = jnp.asarray((rng.standard_normal(4096) * 1e-3).astype(np.float32))
    widths = [int(code_bits(g, eb)) for eb in (1e-2, 1e-3, 1e-4)]
    assert widths == sorted(widths), widths
    assert all(1 <= w <= 32 for w in widths)


def test_largest_mesh_shape_policy():
    """Maximize devices used; break ties toward more model parallelism."""
    assert largest_mesh_shape(8, 2) == (4, 2)
    assert largest_mesh_shape(8, 4) == (2, 4)
    assert largest_mesh_shape(7, 4) == (7, 1)
    assert largest_mesh_shape(5, 2) == (5, 1)
    assert largest_mesh_shape(6, 2) == (3, 2)
    assert largest_mesh_shape(1, 8) == (1, 1)


@pytest.mark.slow
def test_compressed_psum_trains_multi_device():
    """compressed_psum_tree drives train/loop.py for 2 steps on a (4 data,
    2 model) mesh of 8 fake devices without NaNs."""
    py = textwrap.dedent("""
        import jax, numpy as np
        from repro.data import token_batches
        from repro.dist.elastic import rebuild_mesh
        from repro.models import lm, registry
        from repro.optim import adamw, constant
        from repro.train import init_state, make_train_step, train_loop

        cfg = registry.get_smoke_config('gemma2_2b')
        mesh = rebuild_mesh(jax.devices(), model_parallel=2)
        assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \\
            {'data': 4, 'model': 2}
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(constant(1e-3))
        state = init_state(params, opt, grad_compress=True)
        step = make_train_step(cfg, opt, mesh=mesh, grad_compress=True,
                               rel_eb=1e-3)
        data = token_batches(cfg, 8, 32, seed=0)
        state, rep = train_loop(state, step, data, num_steps=2,
                                log=lambda *_: None)
        assert rep.steps_run == 2, rep.steps_run
        assert all(np.isfinite(l) for l in rep.losses), rep.losses
        for leaf in jax.tree.leaves(state.params):
            assert bool(jax.numpy.all(jax.numpy.isfinite(
                leaf.astype(jax.numpy.float32))))
        print('COMPRESSED-DP-OK', [round(l, 4) for l in rep.losses])
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "COMPRESSED-DP-OK" in out.stdout
