"""CD-stage tests: classification semantics + edge handling."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.critical_points import (MAXIMA, MINIMA, REGULAR, SADDLE,
                                        classify, count_labels,
                                        neighbor_min_max)


def test_single_maximum():
    f = jnp.asarray(np.array([[0, 0, 0], [0, 5, 0], [0, 0, 0]], np.float32))
    lab = classify(f)
    assert int(lab[1, 1]) == MAXIMA


def test_single_minimum():
    f = jnp.asarray(np.array([[1, 1, 1], [1, -5, 1], [1, 1, 1]], np.float32))
    lab = classify(f)
    assert int(lab[1, 1]) == MINIMA


def test_saddle():
    # t,d higher; l,r lower
    f = jnp.asarray(np.array([[9, 5, 9], [1, 3, 1], [9, 5, 9]], np.float32))
    lab = classify(f)
    assert int(lab[1, 1]) == SADDLE


def test_flat_is_regular():
    f = jnp.zeros((5, 7))
    assert bool(jnp.all(classify(f) == REGULAR))


def test_corner_extrema_use_available_neighbors():
    f = jnp.asarray(np.array([[5, 1], [1, 0]], np.float32))
    lab = classify(f)
    assert int(lab[0, 0]) == MAXIMA      # 2-neighbor corner max
    assert int(lab[1, 1]) == MINIMA


def test_paper_fig2_flattening():
    """Center 0.012 vs neighbors 0.01 is a maximum; quantization at
    eps=0.01 flattens it (FN) — the paper's motivating example."""
    from repro.core.quantize import quantize_roundtrip
    f = np.full((3, 3), 0.01, np.float32)
    f[1, 1] = 0.012
    f = jnp.asarray(f)
    assert int(classify(f)[1, 1]) == MAXIMA
    rec = quantize_roundtrip(f, 0.01)
    assert int(classify(rec)[1, 1]) == REGULAR


def test_neighbor_min_max_edges():
    f = jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4))
    nmin, nmax = neighbor_min_max(f)
    assert float(nmin[0, 0]) == 1.0       # right neighbor
    assert float(nmax[0, 0]) == 4.0       # down neighbor
    assert float(nmax[2, 3]) == 10.0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000))
def test_property_kernel_matches_core(seed):
    """Pallas cp_detect kernel == core classify on random fields."""
    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    ny, nx = rng.integers(3, 40), rng.integers(3, 40)
    f = jnp.asarray(rng.standard_normal((ny, nx)).astype(np.float32))
    assert bool(jnp.all(ops.cp_detect(f, backend="interpret") == classify(f)))
