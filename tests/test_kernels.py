"""Per-kernel shape/dtype sweeps: interpret-mode Pallas vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.critical_points import classify
from repro.core.quantize import quantize_roundtrip
from repro.kernels import ops

SEEDS = [0, 1]


@pytest.mark.parametrize("b,k", [(64, 32), (100, 32), (256, 16), (31, 8),
                                 (512, 64)])
@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_szp_quant_kernel(b, k, eb):
    rng = np.random.default_rng(b * k)
    x = jnp.asarray(rng.standard_normal((b, k)).astype(np.float32) * 10)
    out_k = ops.szp_quant(x, eb, backend="interpret")
    out_r = ops.szp_quant(x, eb, backend="jnp")
    for a, r, name in zip(out_k, out_r, ["first", "mags", "signs", "widths"]):
        assert jnp.array_equal(a, r), name


@pytest.mark.parametrize("b,k", [(64, 32), (100, 32), (33, 8)])
def test_szp_dequant_kernel(b, k):
    rng = np.random.default_rng(b + k)
    eb = 1e-3
    x = jnp.asarray(rng.standard_normal((b, k)).astype(np.float32))
    first, mags, signs, widths = ops.szp_quant(x, eb, backend="jnp")
    rec_k = ops.szp_dequant(first, mags, signs, eb, backend="interpret")
    rec_r = ops.szp_dequant(first, mags, signs, eb, backend="jnp")
    np.testing.assert_allclose(np.asarray(rec_k), np.asarray(rec_r),
                               atol=1e-6)
    # and the fused roundtrip respects the error bound
    assert float(jnp.abs(rec_k - x).max()) <= eb * (1 + 1e-5)


@pytest.mark.parametrize("shape", [(64, 64), (100, 130), (7, 9), (128, 256),
                                   (3, 3)])
def test_cp_detect_kernel(shape):
    rng = np.random.default_rng(shape[0])
    f = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    assert bool(jnp.all(ops.cp_detect(f, backend="interpret") == classify(f)))


@pytest.mark.parametrize("shape", [(64, 64), (100, 130), (30, 257)])
@pytest.mark.parametrize("eb", [1e-2, 5e-2])
def test_extrema_restore_kernel(shape, eb):
    rng = np.random.default_rng(shape[1])
    f = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    recon = quantize_roundtrip(f, eb)
    labels, cur = classify(f), classify(recon)
    ranks = jnp.asarray(rng.integers(1, 9, shape).astype(np.int32))
    out_k = ops.extrema_restore(recon, labels, cur, ranks, eb,
                                backend="interpret")
    out_r = ops.extrema_restore(recon, labels, cur, ranks, eb, backend="jnp")
    assert jnp.array_equal(out_k, out_r), float(jnp.abs(out_k - out_r).max())


@pytest.mark.parametrize("shape", [(64, 64), (50, 70), (33, 129)])
@pytest.mark.parametrize("sigma,radius", [(0.75, 2), (0.5, 1), (1.0, 3)])
def test_shepard_kernel(shape, sigma, radius):
    rng = np.random.default_rng(int(sigma * 100))
    f = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    out_k = ops.shepard_refine(f, sigma, radius, backend="interpret")
    out_r = ops.shepard_refine(f, sigma, radius, backend="jnp")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_bad_backend_raises():
    with pytest.raises(ValueError):
        ops.cp_detect(jnp.zeros((4, 4)), backend="bogus")
