"""Coordinated multi-process checkpoint commit (repro.ckpt.coord): the
filesystem barrier, single-committer election/merge, crash windows at
every protocol point, and shard-coverage validation on restore.

Most tests drive ``_write_v2_coord`` directly with hand-built per-process
``LeafSnap`` halves — a single JAX process addresses all shards, so the
manager's own ``snapshot_tree`` cannot produce disjoint per-process shard
sets — running the "processes" as threads (the protocol only touches the
shared directory, never process state).  One test runs two REAL OS
processes against a shared directory to prove the protocol needs no
shared memory.
"""
import json
import os
import subprocess
import sys
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.ckpt import BarrierTimeout, CheckpointManager
from repro.ckpt import coord
from repro.ckpt import manifest as mf
from repro.ckpt.manager import _write_v2_coord
from repro.ckpt.sharded import LeafSnap, ShardSnap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _no_fault_leak():
    yield
    faults.clear()


def _field(ny=32, nx=24, seed=0):
    return np.random.default_rng(seed).standard_normal(
        (ny, nx)).astype(np.float32)


def _row_half_snaps(arr, pid, world=2, name="w"):
    """The LeafSnap a process holding rows [pid*ny/world, ...) would
    snapshot: only ITS half, with the global [start, stop) index."""
    ny = arr.shape[0]
    lo, hi = pid * ny // world, (pid + 1) * ny // world
    return [LeafSnap(name, tuple(arr.shape), str(arr.dtype), None,
                     [ShardSnap(((lo, hi), (0, arr.shape[1])),
                                arr[lo:hi])])]


def _run_coord(d, step, snaps, pid, world, timeout_s=30.0, keep=None,
               errs=None):
    try:
        _write_v2_coord(str(d), step, snaps, None, "raw", 1e-4, 4096,
                        keep, None, None, pid, world, timeout_s)
    except BaseException as e:           # noqa: BLE001 — recorded for asserts
        if errs is None:
            raise
        errs[pid] = e


def _coord_threads(d, step, arr, world=2, timeout_s=30.0, errs=None):
    ts = [threading.Thread(target=_run_coord,
                           args=(d, step, _row_half_snaps(arr, p, world), p,
                                 world, timeout_s, None, errs))
          for p in range(world)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(60)
    return errs


# --------------------------------------------------------------------------
# happy path: barrier + merge + single publish
# --------------------------------------------------------------------------

def test_coordinated_commit_merges_both_processes(tmp_path):
    arr = _field()
    _coord_threads(tmp_path, 5, arr)
    final = tmp_path / "step_00000005"
    assert final.is_dir() and not (tmp_path / "step_00000005.tmp").exists()
    doc = json.load(open(final / "manifest.json"))
    assert doc["process_count"] == 2
    assert sorted(sh["file"] for sh in doc["leaves"][0]["shards"]) == \
        ["shards_p0000.bin", "shards_p0001.bin"]
    mf.check_coverage(doc)               # the merge tiles the leaf exactly
    assert not list(final.glob("ready.*"))   # markers are protocol state

    # restore reassembles the halves — on any manager, no world needed
    mgr = CheckpointManager(str(tmp_path), log=None)
    res = mgr.restore({"w": jnp.zeros(arr.shape, jnp.float32)})
    assert res.step == 5
    assert np.array_equal(np.asarray(res.tree["w"]), arr)


def test_late_joiner_within_timeout_commits(tmp_path):
    arr = _field(seed=1)

    def late(pid):
        if pid == 1:
            time.sleep(0.3)              # well inside the barrier timeout
        _run_coord(tmp_path, 2, _row_half_snaps(arr, pid), pid, 2)

    ts = [threading.Thread(target=late, args=(p,)) for p in range(2)]
    [t.start() for t in ts]
    [t.join(60) for t in ts]
    mgr = CheckpointManager(str(tmp_path), log=None)
    res = mgr.restore({"w": jnp.zeros(arr.shape, jnp.float32)})
    assert res.step == 2
    assert np.array_equal(np.asarray(res.tree["w"]), arr)


def test_two_real_processes_commit_over_shared_dir(tmp_path):
    """The protocol's only medium is the shared directory: two separate
    OS processes (no threads, no shared memory) commit one checkpoint."""
    py = (
        "import sys, numpy as np\n"
        "from repro.ckpt.manager import _write_v2_coord\n"
        "from repro.ckpt.sharded import LeafSnap, ShardSnap\n"
        "d, pid = sys.argv[1], int(sys.argv[2])\n"
        "arr = np.arange(48, dtype=np.float32).reshape(8, 6)\n"
        "lo, hi = pid * 4, pid * 4 + 4\n"
        "snaps = [LeafSnap('w', (8, 6), 'float32', None,\n"
        "                  [ShardSnap(((lo, hi), (0, 6)), arr[lo:hi])])]\n"
        "_write_v2_coord(d, 7, snaps, None, 'raw', 1e-4, 4096, None,\n"
        "                None, None, pid, 2, 60.0)\n"
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    procs = [subprocess.Popen([sys.executable, "-c", py, str(tmp_path),
                               str(p)], env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for p in range(2)]
    for p in procs:
        _, err = p.communicate(timeout=240)
        assert p.returncode == 0, err
    mgr = CheckpointManager(str(tmp_path), log=None)
    res = mgr.restore({"w": jnp.zeros((8, 6), jnp.float32)})
    assert res.step == 7
    assert np.array_equal(np.asarray(res.tree["w"]),
                          np.arange(48, dtype=np.float32).reshape(8, 6))


# --------------------------------------------------------------------------
# crash windows: every abort leaves NO commit marker
# --------------------------------------------------------------------------

def test_barrier_timeout_when_peer_never_arrives(tmp_path):
    arr = _field()
    # a prior good checkpoint the job must be able to fall back to
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    mgr.save({"w": jnp.asarray(arr)}, 1)

    with pytest.raises(BarrierTimeout):
        _run_coord(tmp_path, 2, _row_half_snaps(arr, 0), 0, 2,
                   timeout_s=0.3)
    assert not (tmp_path / "step_00000002").exists()   # never published
    res = CheckpointManager(str(tmp_path), log=None).restore(
        {"w": jnp.zeros(arr.shape, jnp.float32)})
    assert res.step == 1                               # fell back cleanly


def test_crash_before_barrier_abandons_checkpoint(tmp_path):
    """A process killed after its blob but before its READY marker: the
    survivor's barrier expires and the checkpoint is abandoned — no
    manifest anywhere, tmp left for the next attempt to reuse."""
    arr = _field()
    faults.install(faults.FaultPlan({
        "ckpt.before_barrier": faults.Fault("crash", times=1)}))
    errs = {}
    _coord_threads(tmp_path, 3, arr, timeout_s=0.5, errs=errs)
    kinds = sorted(type(e).__name__ for e in errs.values())
    assert kinds == ["BarrierTimeout", "InjectedCrash"], errs
    assert not (tmp_path / "step_00000003").exists()
    assert (tmp_path / "step_00000003.tmp").is_dir()   # torn tmp, no marker
    assert CheckpointManager(str(tmp_path), log=None).restore(
        {"w": jnp.zeros(arr.shape, jnp.float32)}) is None


def test_crash_before_manifest_committer_death_leaves_no_manifest(tmp_path):
    """The committer dies AFTER the merge, BEFORE the manifest: the
    non-committer's publish wait expires; nothing is restorable from this
    step and the torn tmp holds no commit marker."""
    arr = _field()
    faults.install(faults.FaultPlan({
        "ckpt.before_manifest": faults.Fault("crash", times=1)}))
    errs = {}
    # the survivor's publish wait runs this timeout to completion; the
    # barrier half must never expire (markers outlive a pre-manifest
    # committer death by construction), so it only needs slack for two
    # thread marker writes under a loaded machine
    _coord_threads(tmp_path, 4, arr, timeout_s=2.0, errs=errs)
    kinds = sorted(type(e).__name__ for e in errs.values())
    assert kinds == ["CommitTimeout", "InjectedCrash"], errs
    assert not (tmp_path / "step_00000004").exists()
    assert not (tmp_path / "step_00000004.tmp" / "manifest.json").exists()
    assert CheckpointManager(str(tmp_path), log=None).restore(
        {"w": jnp.zeros(arr.shape, jnp.float32)}) is None


def test_retry_after_abort_reuses_the_shared_tmp(tmp_path):
    """An aborted attempt (torn tmp with one stale blob + marker) must not
    poison the NEXT attempt of the same step: each process clears only its
    own stale files and the barrier sees exactly world fresh markers."""
    arr = _field()
    faults.install(faults.FaultPlan({
        "ckpt.before_barrier": faults.Fault("crash", times=1)}))
    errs = {}
    _coord_threads(tmp_path, 6, arr, timeout_s=0.5, errs=errs)
    assert not (tmp_path / "step_00000006").exists()
    faults.clear()
    _coord_threads(tmp_path, 6, arr)                   # retry commits
    res = CheckpointManager(str(tmp_path), log=None).restore(
        {"w": jnp.zeros(arr.shape, jnp.float32)})
    assert res.step == 6
    assert np.array_equal(np.asarray(res.tree["w"]), arr)


# --------------------------------------------------------------------------
# marker / fragment validation (committer side)
# --------------------------------------------------------------------------

def test_stale_marker_from_another_commit_rejected(tmp_path):
    os.makedirs(tmp_path / "s")
    coord.write_ready(str(tmp_path / "s"), 0, step=9, world=1,
                      fname="shards_p0000.bin", nbytes=0, mesh_shape=None,
                      entries=[])
    (tmp_path / "s" / "shards_p0000.bin").write_bytes(b"")
    with pytest.raises(IOError, match="another commit"):
        coord.load_fragments(str(tmp_path / "s"), step=8, world=1)


def test_marker_nbytes_mismatch_rejected(tmp_path):
    os.makedirs(tmp_path / "s")
    coord.write_ready(str(tmp_path / "s"), 0, step=1, world=1,
                      fname="shards_p0000.bin", nbytes=64, mesh_shape=None,
                      entries=[])
    (tmp_path / "s" / "shards_p0000.bin").write_bytes(b"\x00" * 32)
    with pytest.raises(IOError, match="torn write"):
        coord.load_fragments(str(tmp_path / "s"), step=1, world=1)


def test_merge_rejects_metadata_disagreement():
    def frag(pid, shape):
        return {"pid": pid, "step": 1, "world": 2, "mesh": None,
                "file": mf.blob_file(pid), "nbytes": 0,
                "leaves": [{"name": "w", "shape": shape,
                            "dtype": "float32", "mode": "raw",
                            "spec": None, "shards": []}]}
    with pytest.raises(IOError, match="disagree on w.shape"):
        coord.merge_fragments([frag(0, [8, 6]), frag(1, [6, 8])], 1, 2)


def test_barrier_satisfied_by_published_commit(tmp_path):
    """Publish race: a fast committer consumes the markers and renames
    tmp away before a slow peer re-polls — seeing the published manifest
    must satisfy the peer's barrier instead of stranding it to timeout."""
    final = tmp_path / "step_00000001"
    os.makedirs(final)
    (final / "manifest.json").write_text("{}")
    pids = coord.wait_for_ready(str(tmp_path / "step_00000001.tmp"), 2,
                                timeout_s=1.0, final=str(final))
    assert pids == [0, 1]


def test_extra_ready_marker_fails_the_barrier(tmp_path):
    """Markers beyond world (stale pids from a larger previous job) are a
    protocol violation, not silently merged."""
    os.makedirs(tmp_path / "s")
    for pid in (0, 2):                                 # pid 2 of world 2?!
        coord.write_ready(str(tmp_path / "s"), pid, step=1, world=2,
                          fname=mf.blob_file(pid), nbytes=0,
                          mesh_shape=None, entries=[])
    with pytest.raises(IOError, match="do not match world"):
        coord.wait_for_ready(str(tmp_path / "s"), 2, timeout_s=1.0)


# --------------------------------------------------------------------------
# shard-coverage validation on restore
# --------------------------------------------------------------------------

def _forge_manifest(final, mutate):
    doc = json.load(open(os.path.join(final, "manifest.json")))
    mutate(doc)
    json.dump(doc, open(os.path.join(final, "manifest.json"), "w"))


def test_coverage_rejects_shard_subset_manifest(tmp_path):
    """A manifest listing only one process's shards (the partial commit a
    crashed committer could in principle produce) restores NOTHING: the
    coverage check detects the gap from metadata alone and falls back."""
    arr = _field()
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    mgr.save({"w": jnp.asarray(arr)}, 1)               # good fallback
    _coord_threads(tmp_path, 2, arr)
    final = str(tmp_path / "step_00000002")

    def drop_p1(doc):
        e = doc["leaves"][0]
        e["shards"] = [sh for sh in e["shards"]
                       if sh["file"] == "shards_p0000.bin"]
    _forge_manifest(final, drop_p1)
    logs = []
    res = CheckpointManager(str(tmp_path), log=logs.append).restore(
        {"w": jnp.zeros(arr.shape, jnp.float32)})
    assert res.step == 1                               # fell back past it
    assert any("partial commit" in ln for ln in logs), logs


def test_coverage_rejects_overlapping_shards(tmp_path):
    arr = _field()
    _coord_threads(tmp_path, 1, arr)
    final = str(tmp_path / "step_00000001")

    def overlap(doc):
        e = doc["leaves"][0]
        e["shards"][1]["index"] = e["shards"][0]["index"]
    _forge_manifest(final, overlap)
    logs = []
    assert CheckpointManager(str(tmp_path), log=logs.append).restore(
        {"w": jnp.zeros(arr.shape, jnp.float32)}) is None
    assert any("overlapping shards" in ln for ln in logs), logs


def test_check_coverage_accepts_exact_tiling():
    doc = mf.build(1, [mf.leaf_entry("w", (8, 6), "float32", "raw", 0, None,
                                     [{"file": "f", "offset": 0,
                                       "nbytes": 96, "sha256": "",
                                       "index": [[0, 4], [0, 6]]},
                                      {"file": "f", "offset": 96,
                                       "nbytes": 96, "sha256": "",
                                       "index": [[4, 8], [0, 6]]}])],
                   None, 2)
    mf.check_coverage(doc)                             # no raise


def test_check_coverage_rejects_out_of_bounds():
    doc = mf.build(1, [mf.leaf_entry("w", (8, 6), "float32", "raw", 0, None,
                                     [{"file": "f", "offset": 0,
                                       "nbytes": 0, "sha256": "",
                                       "index": [[0, 9], [0, 6]]}])],
                   None, 1)
    with pytest.raises(IOError, match="out of bounds"):
        mf.check_coverage(doc)


def test_check_coverage_rejects_duplicate_scalar_shards():
    doc = mf.build(1, [mf.leaf_entry("s", (), "int32", "raw", 0, None,
                                     [{"file": "f", "offset": 0,
                                       "nbytes": 4, "sha256": "",
                                       "index": []},
                                      {"file": "f", "offset": 4,
                                       "nbytes": 4, "sha256": "",
                                       "index": []}])],
                   None, 2)
    with pytest.raises(IOError, match="overlapping"):
        mf.check_coverage(doc)


# --------------------------------------------------------------------------
# manager-level routing
# --------------------------------------------------------------------------

def test_manager_world1_coordinated_matches_plain(tmp_path):
    """coordinated=True with world 1 runs the full protocol (ready marker,
    barrier, self-election, merge) and produces a checkpoint a plain
    manager restores bit-exactly — the basis of the bench's
    commit_barrier_overhead measurement."""
    tree = {"w": jnp.asarray(_field()), "n": jnp.int32(3)}
    mgr = CheckpointManager(str(tmp_path / "coord"), async_write=False,
                            log=None, coordinated=True, process_index=0,
                            process_count=1)
    mgr.save(tree, 4)
    doc = json.load(open(tmp_path / "coord" / "step_00000004"
                         / "manifest.json"))
    assert doc["process_count"] == 1
    res = CheckpointManager(str(tmp_path / "coord"), log=None).restore(
        {"w": jnp.zeros((32, 24), jnp.float32), "n": jnp.int32(0)})
    assert res.step == 4
    assert np.array_equal(np.asarray(res.tree["w"]),
                          np.asarray(tree["w"]))
    assert int(res.tree["n"]) == 3
