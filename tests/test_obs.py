"""repro.obs coverage: span nesting + thread-safety, counter/gauge
exactness against the ring wire model and the serve engine's own
accounting, Chrome-trace/JSONL export validity, the async-writer error
surface, and the zero-sync regression proof (transfer_guard + single-jit
round-trip with obs ENABLED)."""
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.ckpt import AsyncWriteError, AsyncWriter, CheckpointManager
from repro.core.szp import szp_compress, szp_decompress
from repro.core.toposzp import toposzp_compress, toposzp_decompress
from repro.dist.collectives import compressed_psum_tree
from repro.dist.compat import shard_map
from repro.dist.ring import packed_wire_summary
from repro.models import lm, registry
from repro.obs.registry import Registry, _env_enabled
from repro.serve import ContinuousServeEngine, Request


@pytest.fixture(autouse=True)
def _obs_isolation():
    """Every test starts from a clean registry and leaves the process-wide
    enable flag the way it found it (the CI REPRO_OBS=1 leg runs this file
    with obs already on)."""
    was = obs.enabled()
    obs.reset()
    yield
    obs.default_registry().close_jsonl()
    obs.set_enabled(was)
    obs.reset()


def _field(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# primitives: disabled path, spans, metrics
# --------------------------------------------------------------------------

def test_disabled_is_noop():
    """Disabled, every entry point short-circuits: the shared NULL_SPAN,
    no counters, no events."""
    obs.disable()
    assert obs.span("x") is obs.NULL_SPAN
    assert obs.span("y", a=1) is obs.NULL_SPAN
    with obs.span("x"):
        obs.counter_add("c", 5)
        obs.gauge_set("g", 1.0)
        obs.observe("h", 0.5)
        obs.error("e", "boom")
    snap = obs.snapshot()
    assert snap["counters"] == {} and snap["gauges"] == {}
    assert snap["histograms"] == {} and snap["events"] == 0


def test_env_var_parses_truthy():
    import os
    old = os.environ.get("REPRO_OBS")
    try:
        for v, want in (("1", True), ("true", True), ("ON", True),
                        ("0", False), ("", False), ("no", False)):
            os.environ["REPRO_OBS"] = v
            assert _env_enabled() is want
        os.environ.pop("REPRO_OBS")
        assert _env_enabled() is False
    finally:
        if old is None:
            os.environ.pop("REPRO_OBS", None)
        else:
            os.environ["REPRO_OBS"] = old


def test_span_nesting_depth_and_order():
    obs.enable()
    with obs.span("outer", cat="test", k=1):
        with obs.span("inner"):
            pass
    evs = obs.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert outer["depth"] == 0 and inner["depth"] == 1
    assert outer["ph"] == "X" and inner["ph"] == "X"
    assert outer["ts"] <= inner["ts"]
    assert outer["dur"] >= inner["dur"] >= 0.0
    assert outer["args"] == {"k": 1} and outer["cat"] == "test"
    snap = obs.snapshot()
    assert snap["histograms"]["outer"]["count"] == 1
    assert snap["histograms"]["inner"]["count"] == 1


def test_span_records_exception_and_propagates():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("failing"):
            raise ValueError("boom")
    (ev,) = obs.events()
    assert ev["args"]["error"] == "ValueError"


def test_counter_gauge_histogram_exactness():
    obs.enable()
    for v in (1, 2, 3):
        obs.counter_add("c", v)
    obs.gauge_set("g", 7.0)
    obs.gauge_set("g", 9.0)                         # last write wins
    for v in (0.5, 1.5, 1.0):
        obs.observe("h", v)
    snap = obs.snapshot()
    assert snap["counters"]["c"] == 6
    assert snap["gauges"]["g"] == 9.0
    h = snap["histograms"]["h"]
    assert h["count"] == 3 and h["sum"] == 3.0
    assert h["min"] == 0.5 and h["max"] == 1.5 and h["last"] == 1.0
    assert h["mean"] == 1.0


def test_summary_line_prefix_filter():
    obs.enable()
    obs.counter_add("a.c", 2)
    obs.gauge_set("b.g", 3.5)
    line = obs.summary_line()
    assert "a.c=2" in line and "b.g=3.5" in line
    assert "b.g" not in obs.summary_line(("a.",))
    assert obs.summary_line(("zz.",)) == "(no metrics)"


def test_registry_thread_safety_and_per_thread_depth():
    reg = Registry()
    n_threads, n_iter = 8, 200
    depths = []

    def work(i):
        for _ in range(n_iter):
            reg.counter_add("c", 1)
        with obs.Span("t", "span", {}, reg):
            depths.append(reg._depth())     # each thread nests from 0

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    assert snap["counters"]["c"] == n_threads * n_iter
    assert snap["events"] == n_threads
    assert depths == [1] * n_threads


def test_event_buffer_bound_counts_drops():
    reg = Registry(max_events=3)
    for i in range(5):
        reg.record_event({"name": f"e{i}", "ph": "X"})
    assert len(reg.events()) == 3
    assert reg.snapshot()["dropped_events"] == 2


# --------------------------------------------------------------------------
# export: Chrome trace + JSONL
# --------------------------------------------------------------------------

def test_chrome_trace_doc_is_valid(tmp_path):
    obs.enable()
    with obs.span("host.tick"):
        pass
    w = AsyncWriter()
    w.submit(lambda: time.sleep(0.005), label="step 1")
    w.wait()

    path = str(tmp_path / "trace.json")
    assert obs.export_chrome_trace(path) == path
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in spans} >= {"host.tick", "ckpt.write"}
    for e in spans:
        assert {"name", "cat", "ts", "dur", "pid", "tid"} <= set(e)
    # the writer daemon thread gets its own labeled track
    labels = {m["args"]["name"] for m in metas}
    assert "main" in labels and any(lb.startswith("thread-")
                                    for lb in labels)
    main_tid = threading.main_thread().ident
    tids = {e["tid"] for e in spans}
    assert main_tid in tids and len(tids) == 2
    assert "counters" in doc["otherData"]


def test_jsonl_sink_streams_events(tmp_path):
    obs.enable()
    path = str(tmp_path / "events.jsonl")
    obs.configure(jsonl=path)
    with obs.span("a"):
        pass
    obs.error("a", "oops", code=3)
    obs.default_registry().close_jsonl()
    lines = [json.loads(ln) for ln in open(path) if ln.strip()]
    assert [ev["name"] for ev in lines] == ["a", "a"]
    assert lines[1]["ph"] == "i" and lines[1]["args"]["message"] == "oops"

    dump = str(tmp_path / "dump.jsonl")
    obs.export_jsonl(dump)
    assert len([1 for _ in open(dump)]) == len(obs.events())


# --------------------------------------------------------------------------
# ring / collectives: gauges match the static wire model exactly
# --------------------------------------------------------------------------

def _psum_once(g, wire_format):
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))

    def f(gs):
        gl = gs.reshape(-1)
        tree = {"a": gl[: gl.shape[0] // 2], "b": gl[gl.shape[0] // 2:]}
        gbar, _ = compressed_psum_tree(tree, "data", rel_eb=1e-3,
                                       wire_format=wire_format)
        return gbar["a"], gbar["b"]

    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                             out_specs=(P(), P()),
                             check_vma=False))(g.reshape(1, -1))


def test_ring_gauges_match_packed_wire_summary():
    obs.enable()
    g = _field((4096,), seed=0) * 1e-3
    jax.block_until_ready(_psum_once(g, "packed"))
    snap = obs.snapshot()
    want = packed_wire_summary([2048, 2048], 1e-3, 0.0, 1)
    for k in ("n_members", "hops", "base_width_bits",
              "packed_bytes_per_hop", "packed_bytes_per_step",
              "sidecar_idx_bytes", "sidecar_val_bytes",
              "int32_bytes_per_hop", "int32_bytes_per_step"):
        assert snap["gauges"][f"ring.{k}"] == float(want[k]), k
    assert snap["counters"]["ring.traces"] >= 1


def test_collectives_int32_gauges():
    obs.enable()
    g = _field((4096,), seed=1) * 1e-3
    jax.block_until_ready(_psum_once(g, "int32"))
    snap = obs.snapshot()
    assert snap["gauges"]["collectives.leaves"] == 2
    assert snap["gauges"]["collectives.elems_per_step"] == 4096
    assert snap["gauges"]["collectives.n_members"] == 1
    assert snap["counters"]["collectives.traces"] >= 1


# --------------------------------------------------------------------------
# compressor counters
# --------------------------------------------------------------------------

def test_compress_counters_and_stage_histograms():
    obs.enable()
    f = _field((64, 96), seed=2)
    comp = toposzp_compress(f, 1e-3, backend="jnp")
    toposzp_decompress(comp, (64, 96), 1e-3, backend="jnp")
    snap = obs.snapshot()
    c = snap["counters"]
    assert c["toposzp.compress.calls"] == 1
    assert c["toposzp.compress.classic_calls"] == 1
    assert c["toposzp.decompress.calls"] == 1
    assert c["toposzp.compress.cap_bytes"] > 0
    assert any(k.startswith("toposzp.compress.bucket_") for k in c)
    h = snap["histograms"]
    assert h["compress.quant"]["count"] == 1
    assert h["compress.pack"]["count"] == 1
    assert h["decompress.restore"]["count"] == 1


def test_zero_sync_with_obs_enabled():
    """PR 7's structural guarantees survive instrumentation: the resident
    compress runs under transfer_guard('disallow') and the round-trip
    traces under ONE enclosing jit, with obs ON the whole time."""
    obs.enable()
    f = _field((64, 96), seed=3)
    eb = jnp.float32(1e-3)
    jax.block_until_ready(
        toposzp_compress(f, eb, resident=True, backend="jnp"))
    with jax.transfer_guard("disallow"):
        jax.block_until_ready(
            toposzp_compress(f, eb, resident=True, backend="jnp"))

    @jax.jit
    def roundtrip(x, eb):
        parts = szp_compress(x, eb, resident=True, backend="jnp")
        return szp_decompress(parts, (64, 96), eb, backend="jnp")

    out = jax.block_until_ready(roundtrip(f, eb))
    assert float(jnp.max(jnp.abs(out - f))) <= 2e-3
    assert obs.snapshot()["counters"]["toposzp.compress.resident_calls"] >= 1


# --------------------------------------------------------------------------
# serve: counters must equal the engine's own accounting
# --------------------------------------------------------------------------

def test_serve_counters_match_report():
    obs.enable()
    cfg = registry.get_smoke_config("gemma2_2b").replace(
        activation_dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    specs = [(6, 5), (9, 4), (6, 3)]
    reqs = [Request(rid=i, inputs={"tokens": jax.random.randint(
                        jax.random.PRNGKey(40 + i), (1, plen), 0,
                        cfg.vocab_size)},
                    max_new_tokens=new)
            for i, (plen, new) in enumerate(specs)]
    eng = ContinuousServeEngine(cfg, params, max_len=16, num_slots=2,
                                page_size=8, kv_mode="szp", kv_eb=0.16)
    obs.reset()
    rep = eng.serve(reqs)

    assert rep.obs is not None
    c = rep.obs["counters"]
    assert c["serve.admitted"] == len(reqs)
    assert c["serve.evicted"] == len(reqs)
    assert c["serve.decode_steps"] == len(rep.step_times)
    assert c.get("serve.pages_compressed", 0) == \
        rep.pool_stats["pages_compressed"]
    assert rep.obs["histograms"]["serve.step_time_s"]["count"] == \
        len(rep.step_times)
    assert rep.obs["gauges"]["serve.resident_bytes"] >= 0

    obs.disable()
    rep2 = eng.serve(reqs)
    assert rep2.obs is None


# --------------------------------------------------------------------------
# ckpt: async-writer error surface + step/leaf attribution
# --------------------------------------------------------------------------

def test_async_writer_wraps_labeled_failure():
    obs.enable()
    w = AsyncWriter()

    def boom():
        raise IOError("disk gone")

    w.submit(boom, label="step 7")
    with pytest.raises(AsyncWriteError) as ei:
        w.wait()
    assert ei.value.label == "step 7"
    assert isinstance(ei.value.__cause__, IOError)
    assert "step 7" in str(ei.value) and "disk gone" in str(ei.value)
    snap = obs.snapshot()
    assert snap["counters"]["ckpt.write.errors"] == 1
    errs = [e for e in obs.events() if e.get("cat") == "error"]
    assert errs and errs[0]["args"]["label"] == "step 7"
    assert "disk gone" in errs[0]["args"]["message"]


def test_async_writer_bare_submission_keeps_exception_type():
    w = AsyncWriter()

    def boom():
        raise IOError("disk gone")

    w.submit(boom)                      # no label: original type surfaces
    with pytest.raises(IOError, match="disk gone"):
        w.wait()


def test_ckpt_manager_failure_names_step_and_leaf(tmp_path, monkeypatch):
    obs.enable()
    tree = {"w": jnp.zeros((64, 64), jnp.float32)}
    mgr = CheckpointManager(str(tmp_path), mode="raw", async_write=True,
                            verify_restore=False, log=None)

    def boom(*a, **k):
        raise IOError("disk gone")

    monkeypatch.setattr("repro.ckpt.sharded.encode_shards", boom)
    mgr.save(tree, step=3)
    with pytest.raises(AsyncWriteError) as ei:
        mgr.wait()
    assert ei.value.label == "step 3"
    cause = ei.value.__cause__
    assert isinstance(cause, RuntimeError)
    assert "step 3" in str(cause) and "'w'" in str(cause)
    assert "disk gone" in str(cause)
    snap = obs.snapshot()
    assert snap["counters"]["ckpt.submits"] == 1
    assert snap["counters"]["ckpt.write.errors"] == 1
    assert snap["gauges"]["ckpt.queue_depth"] == 0
    assert snap["histograms"]["ckpt.submit_stall_s"]["count"] == 1


def test_ckpt_save_records_spans_and_commit(tmp_path):
    obs.enable()
    tree = {"w": jnp.ones((64, 64), jnp.float32)}
    mgr = CheckpointManager(str(tmp_path), mode="raw", async_write=False,
                            verify_restore=False, log=None)
    path = mgr.save(tree, step=1)
    assert path is not None
    snap = obs.snapshot()
    assert snap["counters"]["ckpt.commits"] == 1
    assert snap["counters"]["ckpt.blob_bytes"] == 64 * 64 * 4
    names = {e["name"] for e in obs.events()}
    assert {"ckpt.save", "ckpt.snapshot", "ckpt.write_blobs",
            "ckpt.commit"} <= names


# --------------------------------------------------------------------------
# bench plumbing: legacy bench-name alias
# --------------------------------------------------------------------------

def test_check_regression_accepts_legacy_serve_name():
    from benchmarks.check_regression import canonical_bench
    assert canonical_bench("serve") == "bench_serve"
    assert canonical_bench("bench_serve") == "bench_serve"
    assert canonical_bench("bench_fig7_time") == "bench_fig7_time"
