"""TopoSZp-3D extension: guarantees carry over to 3-D fields."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topo3d import (MAXIMA, MINIMA, classify3d, false_cases3d,
                               toposzp3d_compress, toposzp3d_decompress)
from repro.core.quantize import quantize_roundtrip
from repro.core.szp import szp_roundtrip


def _field3d(shape=(24, 28, 32), seed=0):
    rng = np.random.default_rng(seed)
    z, y, x = np.meshgrid(np.linspace(0, 3 * np.pi, shape[0]),
                          np.linspace(0, 3 * np.pi, shape[1]),
                          np.linspace(0, 3 * np.pi, shape[2]),
                          indexing="ij")
    f = (np.sin(x) * np.cos(y) * np.sin(z)
         + 0.05 * rng.standard_normal(shape))
    return jnp.asarray(f.astype(np.float32))


def test_classify3d_extrema():
    f = np.zeros((3, 3, 3), np.float32)
    f[1, 1, 1] = 5.0
    assert int(classify3d(jnp.asarray(f))[1, 1, 1]) == MAXIMA
    f[1, 1, 1] = -5.0
    assert int(classify3d(jnp.asarray(f))[1, 1, 1]) == MINIMA


@pytest.mark.parametrize("eb", [1e-2, 1e-3])
def test_3d_guarantees(eb):
    f = _field3d()
    comp = toposzp3d_compress(f, eb)
    rec = toposzp3d_decompress(comp, f.shape, eb)
    assert float(jnp.abs(rec - f).max()) <= 2 * eb * (1 + 1e-4)
    fc = false_cases3d(f, rec)
    assert fc["FP"] == 0 and fc["FT"] == 0

    # FN reduction vs plain SZp on the same 3-D field
    rec_szp, _ = szp_roundtrip(f, eb)
    fc_szp = false_cases3d(f, rec_szp.reshape(f.shape))
    if fc_szp["FN"] > 10:
        assert fc["FN"] < fc_szp["FN"]


def test_3d_ratio_positive():
    f = _field3d(seed=3)
    comp = toposzp3d_compress(f, 1e-3)
    assert 4 * f.size / int(comp.nbytes) > 1.0
