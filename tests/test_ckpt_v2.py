"""repro.ckpt v2: sharded blobs, lossy leaf modes, async writer, elastic
restore-with-resharding, plus the v1 manager bugfixes (logged skips,
structural re-raise, eb only on lossy entries)."""
import json
import os
import subprocess
import sys
import textwrap
import threading
import time
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.ckpt import (AsyncWriter, CheckpointManager, TreeMismatchError,
                        manager as ckpt)
from repro.core.critical_points import REGULAR, classify
from repro.dist.sharding import adapt_spec, spec_from_json, spec_to_json
from repro.train import TrainState, train_loop

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _smooth(ny=96, nx=128, seed=0):
    rng = np.random.default_rng(seed)
    y, x = np.meshgrid(np.linspace(0, 4 * np.pi, ny),
                       np.linspace(0, 4 * np.pi, nx), indexing="ij")
    return (np.sin(x) * np.cos(y)
            + 0.1 * rng.standard_normal((ny, nx))).astype(np.float32)


def _tree():
    return {"m": jnp.asarray(_smooth(seed=0)),
            "v": jnp.asarray(np.abs(_smooth(seed=1))),
            "small": jnp.ones((8,), jnp.float32),
            "count": jnp.int32(7)}


# --------------------------------------------------------------------------
# v2 roundtrips + manifest schema
# --------------------------------------------------------------------------

def test_v2_raw_roundtrip_bitexact(tmp_path):
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), mode="raw", async_write=False,
                            log=None)
    path = mgr.save(tree, 3)
    res = mgr.restore(tree)
    assert res.step == 3 and res.saved_mesh is None
    for k in tree:
        assert np.array_equal(np.asarray(res.tree[k]), np.asarray(tree[k])), k
    doc = json.load(open(os.path.join(path, "manifest.json")))
    assert doc["version"] == 2
    for e in doc["leaves"]:
        assert e["mode"] == "raw"
        assert "eb" not in e            # meaningless on exact blobs
        assert e["spec"] is None        # no mesh involved


@pytest.mark.parametrize("mode,bound", [("szp", 1.0), ("toposzp", 2.0)])
def test_v2_lossy_modes_hold_their_bound(tmp_path, mode, bound):
    tree = _tree()
    eb = 1e-3
    mgr = CheckpointManager(str(tmp_path), mode=mode, eb=eb,
                            async_write=False, log=None)
    path = mgr.save(tree, 1)
    res = mgr.restore(tree)
    for k in ("m", "v"):
        err = float(jnp.abs(res.tree[k] - tree[k]).max())
        assert err <= bound * eb * (1 + 1e-4), (k, err)
    # exact leaves stay exact
    assert np.array_equal(np.asarray(res.tree["small"]),
                          np.asarray(tree["small"]))
    assert int(res.tree["count"]) == 7
    doc = json.load(open(os.path.join(path, "manifest.json")))
    by = {e["name"]: e for e in doc["leaves"]}
    assert by["m"]["mode"] == mode and by["m"]["eb"] == eb
    assert by["small"]["mode"] == "raw" and "eb" not in by["small"]
    # lossy checkpoint is smaller than the raw bytes of its f32 leaves
    raw = sum(np.asarray(v).nbytes for v in tree.values())
    assert os.path.getsize(os.path.join(path, "shards_p0000.bin")) < raw


def test_toposzp_moments_zero_fp_ft(tmp_path):
    """The acceptance guarantee: optimizer moments saved under toposzp
    restore with every critical point preserved — no false positives, no
    type changes — and the relaxed 2*eb bound held."""
    from repro.optim.adamw import AdamWState

    m, v = _smooth(seed=2), np.abs(_smooth(seed=3))
    opt = AdamWState(jnp.int32(9), {"w": jnp.asarray(_smooth(seed=4))},
                     {"w": jnp.asarray(m)}, {"w": jnp.asarray(v)})
    state = TrainState(jnp.int32(9), {"w": jnp.zeros((4,), jnp.float32)},
                       opt, None)
    eb = 1e-3
    mgr = CheckpointManager(str(tmp_path), mode="toposzp", eb=eb,
                            async_write=False, log=None)
    path = mgr.save(state, 9)
    doc = json.load(open(os.path.join(path, "manifest.json")))
    lossy = [e["name"] for e in doc["leaves"] if e["mode"] == "toposzp"]
    assert ".opt_state/.m/w" in lossy and ".opt_state/.v/w" in lossy
    res = mgr.restore(state)
    for orig, rest in ((m, res.tree.opt_state.m["w"]),
                       (v, res.tree.opt_state.v["w"])):
        rest = np.asarray(rest)
        assert np.abs(rest - orig).max() <= 2 * eb * (1 + 1e-4)
        lo = classify(jnp.asarray(orig))
        lr = classify(jnp.asarray(rest))
        viol = np.asarray((lr != REGULAR) & (lr != lo))
        assert not viol.any(), f"{viol.sum()} FP/FT critical points"


def test_toposzp_guarantee_reverified_on_restore(tmp_path):
    """A tampered toposzp blob that breaks the FP/FT guarantee is rejected
    by the restore-time re-verification (falls back / returns None)."""
    tree = {"m": jnp.asarray(_smooth())}
    mgr = CheckpointManager(str(tmp_path), mode="toposzp", eb=1e-3,
                            async_write=False, log=None, keep=None)
    path = mgr.save(tree, 1)
    blob_path = os.path.join(path, "shards_p0000.bin")
    doc = json.load(open(os.path.join(path, "manifest.json")))
    sh = doc["leaves"][0]["shards"][0]
    # flip bytes inside the stream AND refresh the recorded hash, so only
    # the semantic guarantee check (not the hash) can catch it
    blob = bytearray(open(blob_path, "rb").read())
    off = sh["offset"] + sh["nbytes"] // 2
    for i in range(64):
        blob[off + i] ^= 0xFF
    open(blob_path, "wb").write(bytes(blob))
    import hashlib
    sh["sha256"] = hashlib.sha256(
        bytes(blob[sh["offset"]: sh["offset"] + sh["nbytes"]])).hexdigest()
    json.dump(doc, open(os.path.join(path, "manifest.json"), "w"))
    logs = []
    mgr2 = CheckpointManager(str(tmp_path), mode="toposzp",
                             log=logs.append)
    assert mgr2.restore(tree) is None
    assert any("skipping step 1" in ln for ln in logs), logs


# --------------------------------------------------------------------------
# async writer
# --------------------------------------------------------------------------

def test_async_writer_overlaps_and_barriers():
    w = AsyncWriter()
    started = threading.Event()
    release = threading.Event()

    def slow():
        started.set()
        release.wait(5)
        return "done"

    w.submit(slow)
    started.wait(5)
    assert w.in_flight            # step loop continues while this writes
    release.set()
    assert w.wait() == "done"
    assert not w.in_flight

    # submit barriers on the previous write
    order = []
    w.submit(lambda: order.append("first") or time.sleep(0.05))
    w.submit(lambda: order.append("second"))
    w.wait()
    assert order == ["first", "second"]


def test_async_writer_reraises_background_failure():
    w = AsyncWriter()
    w.submit(lambda: (_ for _ in ()).throw(IOError("disk gone")))
    with pytest.raises(IOError, match="disk gone"):
        w.wait()
    w.submit(lambda: "fine")      # writer stays usable afterwards
    assert w.wait() == "fine"


def test_async_save_through_manager(tmp_path):
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), mode="szp", eb=1e-3,
                            async_write=True, log=None)
    assert mgr.save(tree, 10) is None      # enqueued, not yet committed
    mgr.save(tree, 20)                     # barriers on the previous write
    mgr.wait()
    assert mgr.latest_step() == 20
    assert mgr.restore(tree).step == 20


# --------------------------------------------------------------------------
# preemption / corruption fallback + structural mismatches
# --------------------------------------------------------------------------

def test_midwrite_preemption_falls_back(tmp_path):
    """A kill between blob and manifest leaves a step dir without its
    commit marker (and possibly a stale .tmp): restore must fall back to
    the previous valid checkpoint and say why."""
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), mode="raw", async_write=False,
                            log=None, keep=None)
    mgr.save(tree, 10)
    mgr.save(tree, 20)
    # simulated preemption mid-commit: blobs durable, manifest missing
    part = tmp_path / "step_00000030"
    part.mkdir()
    (part / "shards_p0000.bin").write_bytes(b"\x00" * 128)
    (tmp_path / "step_00000040.tmp").mkdir()   # stale tmp is ignored
    logs = []
    mgr2 = CheckpointManager(str(tmp_path), log=logs.append)
    res = mgr2.restore(tree)
    assert res.step == 20
    assert any("skipping step 30" in ln for ln in logs), logs


def test_corrupt_blob_falls_back_with_reason(tmp_path):
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), mode="raw", async_write=False,
                            log=None, keep=None)
    mgr.save(tree, 10)
    path = mgr.save(tree, 20)
    blob = os.path.join(path, "shards_p0000.bin")
    with open(blob, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    logs = []
    mgr2 = CheckpointManager(str(tmp_path), log=logs.append)
    res = mgr2.restore(tree)
    assert res.step == 10
    assert any("hash mismatch" in ln for ln in logs), logs


def test_v2_structural_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    mgr.save({"a": jnp.ones((4,)), "b": jnp.zeros((4,))}, 1)
    with pytest.raises(TreeMismatchError, match="does not match"):
        mgr.restore({"a": jnp.ones((4,)), "c": jnp.zeros((4,))})


def test_v2_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    mgr.save({"a": jnp.ones((4, 4))}, 1)
    with pytest.raises(TreeMismatchError, match="shape mismatch"):
        mgr.restore({"a": jnp.ones((8, 2))})


def test_v2_dtype_drift_logged_not_silent(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    mgr.save({"a": jnp.ones((4,), jnp.float32)}, 1)
    logs = []
    mgr2 = CheckpointManager(str(tmp_path), log=logs.append)
    assert mgr2.restore({"a": jnp.ones((4,), jnp.float16)}) is None
    assert any("dtype drift" in ln for ln in logs), logs


# --------------------------------------------------------------------------
# v1 manager bugfixes
# --------------------------------------------------------------------------

def test_v1_structural_mismatch_reraises(tmp_path):
    d = str(tmp_path)
    ckpt.save({"a": jnp.ones((4,))}, 1, d)
    with pytest.raises(TreeMismatchError):
        ckpt.restore(d, {"zzz": jnp.ones((4,))})


def test_v1_shape_mismatch_reraises(tmp_path):
    d = str(tmp_path)
    ckpt.save({"a": jnp.ones((4, 4))}, 1, d)
    with pytest.raises(TreeMismatchError, match="shape mismatch"):
        ckpt.restore(d, {"a": jnp.ones((8, 2))})


def test_v1_skips_are_logged_with_reason(tmp_path):
    d = str(tmp_path)
    ckpt.save({"a": jnp.ones((64,), jnp.float32)}, 5, d)
    ckpt.save({"a": jnp.ones((64,), jnp.float32)}, 10, d)
    with open(os.path.join(d, "step_00000010", "data.bin"), "r+b") as f:
        f.write(b"\xff" * 8)
    logs = []
    out = ckpt.restore(d, {"a": jnp.ones((64,), jnp.float32)},
                       log=logs.append)
    assert out is not None and out[1] == 5
    assert any("skipping step 10" in ln and "hash mismatch" in ln
               for ln in logs), logs


def test_v1_dtype_drift_is_a_logged_skip(tmp_path):
    d = str(tmp_path)
    ckpt.save({"a": jnp.ones((4,), jnp.float32)}, 1, d)
    logs = []
    assert ckpt.restore(d, {"a": jnp.ones((4,), jnp.int32)},
                        log=logs.append) is None
    assert any("dtype drift" in ln for ln in logs), logs


def test_v1_eb_recorded_only_for_lossy(tmp_path):
    d = str(tmp_path)
    big = jnp.asarray(np.random.default_rng(0)
                      .standard_normal((128, 64)).astype(np.float32))
    path = ckpt.save({"w": big, "n": jnp.int32(1)}, 1, d, compress="szp")
    doc = json.load(open(os.path.join(path, "manifest.json")))
    by = {e["name"]: e for e in doc["entries"]}
    assert by["w"]["mode"] == "szp" and by["w"]["eb"] == 1e-4
    assert by["n"]["mode"] == "raw" and "eb" not in by["n"]


# --------------------------------------------------------------------------
# spec adaptation (restore-with-resharding building block)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["szp", "toposzp"])
def test_batched_shard_encode_decode_matches_per_shard(mode):
    """encode_shards/decode_shards (one batched compile per leaf) are
    byte/bit-identical to the per-shard encode_shard/decode_shard loop —
    including toposzp rank streams trimmed to DIFFERENT block counts per
    shard (the _stack_szp zero-block padding path)."""
    from repro.ckpt import sharded
    rng = np.random.default_rng(0)
    eb = 1e-3
    datas = []
    for i in range(4):
        d = rng.standard_normal((32, 48)).astype(np.float32)
        if i == 0:
            d[:] = np.round(d * 2) / 2    # few CPs -> short rank stream
        datas.append(d)
    batched = sharded.encode_shards(datas, mode, eb)
    single = [sharded.encode_shard(d, mode, eb) for d in datas]
    assert batched == single
    shapes = [d.shape for d in datas]
    out_b = sharded.decode_shards(batched, mode, np.dtype(np.float32),
                                  shapes)
    out_s = [sharded.decode_shard(b, mode, np.dtype(np.float32), s)
             for b, s in zip(batched, shapes)]
    for a, b, d in zip(out_b, out_s, datas):
        assert np.array_equal(a, b)
        bound = eb if mode == "szp" else 2 * eb
        assert np.abs(a - d).max() <= bound * (1 + 1e-5)
    # mixed shapes fall back to the per-shard loop transparently
    mixed = datas[:2] + [rng.standard_normal((16, 48)).astype(np.float32)]
    enc = sharded.encode_shards(mixed, mode, eb)
    assert enc == [sharded.encode_shard(d, mode, eb) for d in mixed]


def test_spec_json_roundtrip():
    for spec in (P(), P(None, "model"), P(("pod", "data"), None, "model"),
                 P("data")):
        assert tuple(spec_from_json(spec_to_json(spec))) == tuple(spec)


def test_adapt_spec_guards():
    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 2, "model": 2})
    # kept when the axis divides, dropped when it doesn't
    assert tuple(adapt_spec(P("data", "model"), mesh, (8, 6))) == \
        ("data", "model")
    assert tuple(adapt_spec(P("data", "model"), mesh, (7, 6))) == \
        (None, "model")
    # axes the new mesh doesn't have are dropped (pod -> none)
    assert tuple(adapt_spec(P(("pod", "data"), None), mesh, (8, 4))) == \
        ("data", None)
    # multi-axis groups keep only what still divides
    mesh3 = SimpleNamespace(axis_names=("pod", "data"),
                            shape={"pod": 2, "data": 3})
    assert tuple(adapt_spec(P(("pod", "data"),), mesh3, (12,))) == \
        ((("pod", "data")),)
    assert tuple(adapt_spec(P(("pod", "data"),), mesh3, (8,))) == (None,)


def test_shard_state_applies_rule_based_layout():
    """The rule-based resharding helper: params, master weights, both Adam
    moments and the error-feedback tree all land on the mesh with the
    model's sharding rules, values untouched."""
    import jax
    from repro.models import lm, registry
    from repro.optim import adamw, constant
    from repro.train import init_state, shard_state

    cfg = registry.get_smoke_config("minicpm_2b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = init_state(params, adamw(constant(1e-3)), grad_compress=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = shard_state(state, cfg, mesh)
    for tree in (out.params, out.opt_state.master, out.opt_state.m,
                 out.opt_state.v, out.err):
        for leaf in jax.tree.leaves(tree):
            assert leaf.sharding.mesh == mesh
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(out.step) == 0 and int(out.opt_state.step) == 0


# --------------------------------------------------------------------------
# train_loop integration (single device + 8-fake-device elastic subprocess)
# --------------------------------------------------------------------------

def _toy_state(val=0.0):
    params = {"w": jnp.full((64, 32), val, jnp.float32)}
    return TrainState(jnp.int32(0), params, None, None)


def _toy_step(state, batch):
    return (state._replace(step=state.step + 1,
                           params={"w": state.params["w"] + 1.0}),
            {"loss": jnp.float32(0.0)})


def _batches():
    while True:
        yield {"x": jnp.zeros(())}


def test_train_loop_with_manager_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), mode="raw", async_write=True,
                            log=None)
    state1, rep1 = train_loop(_toy_state(), _toy_step, _batches(),
                              num_steps=4, ckpt_manager=mgr, ckpt_every=2,
                              log=lambda *_: None)
    assert rep1.checkpoints == [2, 4]
    assert mgr.latest_step() == 4          # loop waited for the async commit
    # a fresh job restores from step 4 and runs the remaining 2 steps
    mgr2 = CheckpointManager(str(tmp_path), mode="raw", log=None)
    state2, rep2 = train_loop(_toy_state(), _toy_step, _batches(),
                              num_steps=6, ckpt_manager=mgr2, ckpt_every=2,
                              log=lambda *_: None)
    assert rep2.restored_from == 4 and rep2.steps_run == 2
    assert not rep2.resharded
    assert int(state2.step) == 6
    assert float(state2.params["w"][0, 0]) == 6.0


@pytest.mark.slow
def test_elastic_restore_onto_smaller_mesh():
    """Save on a 4x2 mesh, lose half the world, restore through
    train_loop's elastic path onto the rebuilt 2x2 mesh: raw leaves
    bit-correct, toposzp leaves guarantee-correct (2*eb bound + zero
    FP/FT per saved shard)."""
    py = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import CheckpointManager
        from repro.core.critical_points import REGULAR, classify
        from repro.dist.elastic import mesh_shape_dict, rebuild_mesh
        from repro.train import TrainState, train_loop

        mesh1 = rebuild_mesh(jax.devices(), model_parallel=2)
        assert mesh_shape_dict(mesh1) == {'data': 4, 'model': 2}
        rng = np.random.default_rng(0)
        ny, nx = 128, 96
        y, x = np.meshgrid(np.linspace(0, 4*np.pi, ny),
                           np.linspace(0, 4*np.pi, nx), indexing='ij')
        m_host = (np.sin(x)*np.cos(y)
                  + 0.1*rng.standard_normal((ny, nx))).astype(np.float32)
        w_host = rng.standard_normal((ny, nx)).astype(np.float32)

        def put(a, spec):
            return jax.device_put(jnp.asarray(a), NamedSharding(mesh1, spec))

        def step_fn(state, batch):
            return state._replace(step=state.step + 1), \\
                {'loss': jnp.float32(0.0)}

        def batches():
            while True:
                yield {'x': jnp.zeros(())}

        # ---- phase 1: train 2 steps on 4x2, checkpoint every step (raw)
        d = tempfile.mkdtemp()
        params = {'w': put(w_host, P('data', 'model')),
                  'm': put(m_host, P('data', None))}
        state = TrainState(jnp.int32(0), params, None, None)
        mgr = CheckpointManager(d, mode='raw', async_write=True, log=None)
        _, rep1 = train_loop(state, step_fn, batches(), num_steps=2,
                             ckpt_manager=mgr, ckpt_every=1, mesh=mesh1,
                             log=lambda *_: None)
        assert rep1.checkpoints == [1, 2], rep1.checkpoints

        # ---- phase 2: half the devices survive; the loop rebuilds 2x2
        survivors = jax.devices()[:4]
        tpl = TrainState(jnp.int32(0),
                         {'w': jnp.zeros((ny, nx), jnp.float32),
                          'm': jnp.zeros((ny, nx), jnp.float32)},
                         None, None)
        mgr2 = CheckpointManager(d, mode='raw', log=None)
        state2, rep2 = train_loop(tpl, step_fn, batches(), num_steps=3,
                                  ckpt_manager=mgr2, ckpt_every=10,
                                  mesh=None, model_parallel=2,
                                  devices=survivors, log=lambda *_: None)
        assert rep2.restored_from == 2, rep2.restored_from
        assert rep2.resharded
        assert rep2.saved_mesh == {'data': 4, 'model': 2}
        assert rep2.restore_mesh == {'data': 2, 'model': 2}
        assert rep2.steps_run == 1
        # raw leaves restored bit-correct (step_fn is identity on params)
        assert np.array_equal(np.asarray(state2.params['m']), m_host)
        assert np.array_equal(np.asarray(state2.params['w']), w_host)

        # ---- phase 3: toposzp-mode checkpoint resharded 4x2 -> 2x2
        eb = 1e-3
        d2 = tempfile.mkdtemp()
        mgr3 = CheckpointManager(d2, mode='toposzp', eb=eb,
                                 async_write=False, log=None,
                                 min_compress_size=1024)
        st = TrainState(jnp.int32(2), {'m': put(m_host, P('data', None))},
                        None, None)
        mgr3.save(st, 2)
        mesh2 = rebuild_mesh(survivors, model_parallel=2)
        res = mgr3.restore(st, mesh=mesh2)
        out = np.asarray(res.tree.params['m'])
        assert res.tree.params['m'].sharding.mesh.devices.size == 4
        assert np.abs(out - m_host).max() <= 2*eb*(1 + 1e-4)
        # zero FP / zero FT per saved shard (4 row blocks on 'data')
        for rs in range(4):
            blk = slice(rs*ny//4, (rs+1)*ny//4)
            lo = np.asarray(classify(jnp.asarray(m_host[blk])))
            lr = np.asarray(classify(jnp.asarray(out[blk])))
            viol = (lr != REGULAR) & (lr != lo)
            assert not viol.any(), (rs, int(viol.sum()))
        print('ELASTIC-RESHARD-OK')
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ELASTIC-RESHARD-OK" in out.stdout
