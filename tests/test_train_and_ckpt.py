"""Training loop, checkpoint/restart, preemption recovery, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import manager as ckpt
from repro.data import token_batches
from repro.models import lm, registry
from repro.optim import adamw, constant, wsd
from repro.train import (PreemptionError, init_state, make_train_step,
                         train_loop)


def _setup(arch="minicpm_2b", lr=3e-3):
    cfg = registry.get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant(lr))
    state = init_state(params, opt, grad_compress=False)
    step = make_train_step(cfg, opt)
    data = token_batches(cfg, 8, 32, seed=0)
    return cfg, state, step, data


def test_loss_decreases():
    cfg, state, step, data = _setup()
    state, rep = train_loop(state, step, data, num_steps=40,
                            log=lambda *_: None)
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.05, (first, last)


def test_checkpoint_restart_exact(tmp_path):
    cfg, state, step, data = _setup()
    d = str(tmp_path / "ck")
    state1, rep1 = train_loop(state, step, data, num_steps=20, ckpt_dir=d,
                              ckpt_every=10, log=lambda *_: None)
    # a fresh job restores and continues from step 20
    cfg, state0, step2, data2 = _setup()
    state2, rep2 = train_loop(state0, step2, data2, num_steps=25,
                              ckpt_dir=d, ckpt_every=10,
                              log=lambda *_: None)
    assert rep2.restored_from == 20
    assert rep2.steps_run == 5
    assert int(state2.step) == 25


def test_preemption_then_recovery(tmp_path):
    cfg, state, step, data = _setup()
    d = str(tmp_path / "ck")
    with pytest.raises(PreemptionError):
        train_loop(state, step, data, num_steps=30, ckpt_dir=d,
                   ckpt_every=5, preempt_at=17, log=lambda *_: None)
    # restart picks up from the last checkpoint (15), not from scratch
    cfg, state0, step2, data2 = _setup()
    state2, rep = train_loop(state0, step2, data2, num_steps=30, ckpt_dir=d,
                             ckpt_every=5, log=lambda *_: None)
    assert rep.restored_from == 15
    assert int(state2.step) == 30


def test_corrupt_checkpoint_falls_back(tmp_path):
    cfg, state, step, data = _setup()
    d = str(tmp_path / "ck")
    state, rep = train_loop(state, step, data, num_steps=20, ckpt_dir=d,
                            ckpt_every=10, log=lambda *_: None)
    # corrupt the newest checkpoint's data file
    newest = os.path.join(d, "step_00000020", "data.bin")
    with open(newest, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    restored = ckpt.restore(d, state)
    assert restored is not None
    assert restored[1] == 10   # fell back to the previous checkpoint


def test_szp_compressed_checkpoint(tmp_path):
    """Space-saving error-bounded checkpoints honor the bound."""
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32)),
            "step": jnp.int32(7)}
    eb = 1e-4
    path = ckpt.save(tree, 1, str(tmp_path / "c"), compress="szp", eb=eb)
    out, step = ckpt.restore(str(tmp_path / "c"), tree)
    assert step == 1
    assert int(out["step"]) == 7
    err = float(jnp.abs(out["w"] - tree["w"]).max())
    xmax = float(jnp.abs(tree["w"]).max())
    assert err <= eb + 4 * float(np.spacing(np.float32(xmax + eb)))
    # compressed checkpoint is smaller than raw
    raw = 128 * 64 * 4
    size = os.path.getsize(os.path.join(path, "data.bin"))
    assert size < raw


def test_wsd_schedule_shape():
    sched = wsd(1.0, warmup=10, stable=20, decay=10)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == 1.0
    assert float(sched(jnp.int32(25))) == 1.0
    assert float(sched(jnp.int32(40))) <= 0.11
