"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finite values (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm, registry
from repro.optim import adamw, constant
from repro.train import init_state, make_train_step

B, S = 2, 32


def _batch(cfg, rng):
    if cfg.frontend == "audio_frames":
        return {"embeds": jax.random.normal(rng, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_patches":
        return {"patch_embeds": jax.random.normal(
                    rng, (B, cfg.num_prefix_embeds, cfg.d_model),
                    jnp.bfloat16),
                "tokens": jax.random.randint(
                    rng, (B, S - cfg.num_prefix_embeds), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, rng)
    batch = _batch(cfg, rng)

    # forward: hidden shape + finite
    from repro.models.lm import _inputs_to_x, forward
    x = _inputs_to_x(params, cfg, batch)
    h, _, aux = jax.jit(lambda p, xx: forward(p, cfg, xx))(params, x)
    assert h.shape[0] == B and h.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    # one full train step: loss finite, params updated, no NaNs anywhere
    opt = adamw(constant(1e-3))
    state = init_state(params, opt, grad_compress=False)
    step = jax.jit(make_train_step(cfg, opt))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    for leaf in jax.tree.leaves(state2.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["rwkv6_3b", "recurrentgemma_2b",
                                  "gemma3_4b", "olmoe_1b_7b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce prefill logits (cache math)."""
    cfg = registry.get_smoke_config(arch)
    if cfg.num_experts:
        # avoid capacity drops, which legitimately differ between the
        # 12-token forward and the 6+6 prefill/decode split
        cfg = cfg.replace(capacity_factor=8.0)
    rng = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, rng)
    toks = jax.random.randint(rng, (1, 12), 0, cfg.vocab_size)

    # full prefill logits for the whole sequence
    from repro.models.lm import _inputs_to_x, forward, logits_fn
    x = _inputs_to_x(params, cfg, {"tokens": toks})
    h, _, _ = forward(params, cfg, x, mode="train")
    full_logits = logits_fn(params, cfg, h)

    # prefill on the first 6, then decode the rest teacher-forced
    _, caches = lm.prefill(params, cfg, {"tokens": toks[:, :6]})
    from repro.serve.engine import pad_caches
    caches = pad_caches(caches, 12)
    errs = []
    for t in range(6, 12):
        _, logits, caches = lm.decode_step(params, cfg, toks[:, t:t + 1],
                                           caches)
        ref = full_logits[:, t, :]
        errs.append(float(jnp.abs(logits[:, 0, :] - ref).max()))
    assert max(errs) < 0.1, errs   # bf16 accumulation tolerance
