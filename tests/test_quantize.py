"""Quantizer unit + property tests (paper Sec. II-C / III-B invariants)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.quantize import dequantize, quantize, quantize_roundtrip

EBS = [1e-1, 1e-2, 1e-3, 1e-4]


def _tol(eb, xmax):
    """eb plus float32 ULP slop (the C reference uses doubles internally;
    our x32-only JAX build carries a few-ULP slop at |x| >> eb)."""
    return eb + 4 * float(np.spacing(np.float32(xmax + eb)))


@pytest.mark.parametrize("eb", EBS)
def test_error_bound_center(eb):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(10000).astype(np.float32))
    err = jnp.abs(quantize_roundtrip(x, eb) - x)
    assert float(err.max()) <= _tol(eb, float(jnp.abs(x).max()))


def test_paper_example_fig2():
    # paper Fig 2: eps=0.01, values 0.012 and 0.01 land in the same bin
    eb = 0.01
    q = quantize(jnp.array([0.012, 0.01, 0.01, 0.01, 0.01]), eb)
    assert len(set(np.asarray(q).tolist())) == 1   # all flattened to one bin


def test_monotone():
    rng = np.random.default_rng(1)
    x = jnp.asarray(np.sort(rng.standard_normal(5000)).astype(np.float32))
    q = quantize(x, 1e-3)
    assert bool(jnp.all(jnp.diff(q) >= 0))
    r = dequantize(q, 1e-3)
    assert bool(jnp.all(jnp.diff(r) >= 0))


def test_left_mode_bound_is_2eb():
    """The paper's literal reconstruction formula only bounds by 2 eps."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(10000).astype(np.float32))
    eb = 1e-2
    err = jnp.abs(quantize_roundtrip(x, eb, recon="left") - x)
    assert float(err.max()) <= 2 * eb + 1e-8
    assert float(err.max()) > eb          # and it genuinely exceeds eps


@settings(max_examples=200, deadline=None)
@given(st.floats(-1e3, 1e3, allow_nan=False, width=32),
       st.sampled_from(EBS))
def test_property_pointwise_bound(val, eb):
    # |x|/eb must stay below 2^24 for a float32 code path to be exact;
    # the ULP-aware tolerance covers the representability slop.
    x = jnp.float32(val)
    r = quantize_roundtrip(x, eb)
    assert abs(float(r) - float(x)) <= _tol(eb, abs(float(x)))


@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=2, max_size=50), st.sampled_from([1e-2, 1e-3]))
def test_property_order_preserved(vals, eb):
    """Monotonicity: a1 < a2 => a1_hat <= a2_hat (no FP/FT mechanism)."""
    x = jnp.asarray(sorted(vals), jnp.float32)
    r = quantize_roundtrip(x, eb)
    assert bool(jnp.all(jnp.diff(r) >= 0))
