"""Fault-injection chaos suite (repro.faults): every failure mode the
fault-tolerance layer claims to survive, produced on demand.

The invariant asserted throughout: an injected fault either FULLY
recovers (retry absorbed it, or the loop rolled back and kept training)
or fails LOUDLY — and in every case the latest committed checkpoint
stays intact and restorable.

Test names carry the fault keywords the nightly matrix selects with
``-k``: crash_before_barrier (tests/test_ckpt_coord.py),
crash_before_manifest, torn_blob, transient_io, device_loss.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults, obs
from repro.ckpt import AsyncWriteError, AsyncWriter, CheckpointManager
from repro.dist.elastic import DeviceLoss
from repro.train import TrainState, train_loop

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _isolated():
    was = obs.enabled()
    obs.reset()
    faults.clear()
    yield
    faults.clear()
    obs.set_enabled(was)
    obs.reset()


def _field(seed=0):
    return np.random.default_rng(seed).standard_normal(
        (48, 32)).astype(np.float32)


def _counters():
    return obs.default_registry().snapshot()["counters"]


# --------------------------------------------------------------------------
# the switchboard itself
# --------------------------------------------------------------------------

def test_hooks_are_noops_without_a_plan():
    faults.fire("ckpt.write", step=1)          # nothing raised
    assert faults.mangle("ckpt.blob", b"abc") == b"abc"
    assert faults.active() is None


def test_fault_matching_is_deterministic_and_bounded():
    plan = faults.FaultPlan({
        "a": faults.Fault("error", times=2),
        "b": faults.Fault("error", at=5),
    }, seed=7)
    with faults.injected(plan):
        for _ in range(2):
            with pytest.raises(OSError):
                faults.fire("a")
        faults.fire("a")                        # budget of 2 exhausted
        faults.fire("b", step=4)                # wrong step: no fire
        with pytest.raises(OSError):
            faults.fire("b", step=5)
    assert [s for s, _ in plan.fired] == ["a", "a", "b"]
    # an identical plan replays the identical sequence (seeded rng)
    probs = []
    for _ in range(2):
        p = faults.FaultPlan({"a": faults.Fault("error", times=None,
                                                prob=0.5)}, seed=3)
        hits = 0
        for _ in range(20):
            try:
                p.fire("a")
            except OSError:
                hits += 1
        probs.append(hits)
    assert probs[0] == probs[1] and 0 < probs[0] < 20


def test_mangle_flip_and_truncate():
    data = bytes(range(200))
    flipped = faults.FaultPlan(
        {"s": faults.Fault("torn", nbytes=8)}).mangle("s", data)
    assert len(flipped) == len(data) and flipped != data
    cut = faults.FaultPlan(
        {"s": faults.Fault("torn", torn="truncate", nbytes=50)}
    ).mangle("s", data)
    assert cut == data[:150]


# --------------------------------------------------------------------------
# transient IO: the async writer's retry budget
# --------------------------------------------------------------------------

def test_transient_io_absorbed_by_writer_retries(tmp_path):
    """Two transient OSErrors at ckpt.write, retry budget of two: the
    save commits as if nothing happened, and the retries are counted."""
    obs.enable()
    tree = {"w": jnp.asarray(_field())}
    mgr = CheckpointManager(str(tmp_path), async_write=True, log=None,
                            write_retries=2, write_backoff_s=0.001)
    with faults.injected(faults.FaultPlan(
            {"ckpt.write": faults.Fault("error", times=2)})) as plan:
        mgr.save(tree, 1)
        mgr.wait()
        assert len(plan.fired) == 2
    assert mgr.latest_step() == 1
    assert mgr.committed_steps == [1] and mgr.failed_steps == []
    assert _counters().get("ckpt.write_retries") == 2
    res = CheckpointManager(str(tmp_path), log=None).restore(
        {"w": jnp.zeros((48, 32), jnp.float32)})
    assert np.array_equal(np.asarray(res.tree["w"]), _field())


def test_transient_io_exhausting_retries_fails_loudly(tmp_path):
    tree = {"w": jnp.asarray(_field())}
    mgr = CheckpointManager(str(tmp_path), async_write=True, log=None,
                            write_retries=1, write_backoff_s=0.001)
    with faults.injected(faults.FaultPlan(
            {"ckpt.write": faults.Fault("error", times=None)})):
        mgr.save(tree, 1)
        with pytest.raises(AsyncWriteError, match="step 1"):
            mgr.wait()
    assert mgr.committed_steps == []
    assert [s for s, _ in mgr.failed_steps] == [1, 1]   # initial + retry run
    assert mgr.latest_step() is None                    # nothing half-written


def test_writer_retry_reruns_fn_from_scratch():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    w = AsyncWriter(retries=2, backoff_s=0.001)
    w.submit(flaky)
    assert w.wait() == "ok" and len(calls) == 3


# --------------------------------------------------------------------------
# torn blob: corruption between memory and disk
# --------------------------------------------------------------------------

def test_torn_blob_detected_on_restore_and_fallback(tmp_path):
    """Bytes torn on their way to disk while the manifest keeps the hash
    of the intended bytes: restore detects the mismatch and falls back to
    the intact previous checkpoint."""
    tree = {"w": jnp.asarray(_field())}
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    mgr.save(tree, 1)                                   # intact
    with faults.injected(faults.FaultPlan(
            {"ckpt.blob": faults.Fault("torn", nbytes=16)})) as plan:
        mgr.save(tree, 2)                               # torn on disk
        assert len(plan.fired) == 1
    logs = []
    res = CheckpointManager(str(tmp_path), log=logs.append).restore(
        {"w": jnp.zeros((48, 32), jnp.float32)})
    assert res.step == 1
    assert any("skipping step 2" in ln and "hash mismatch" in ln
               for ln in logs), logs


def test_torn_blob_truncation_detected(tmp_path):
    tree = {"w": jnp.asarray(_field()), "n": jnp.int32(1)}
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    with faults.injected(faults.FaultPlan(
            {"ckpt.blob": faults.Fault("torn", torn="truncate",
                                       nbytes=64)})):
        mgr.save(tree, 1)
    logs = []
    assert CheckpointManager(str(tmp_path), log=logs.append).restore(
        {"w": jnp.zeros((48, 32), jnp.float32), "n": jnp.int32(0)}) is None
    assert logs, "truncation must be a logged skip, not silence"


# --------------------------------------------------------------------------
# crash windows (single-controller path)
# --------------------------------------------------------------------------

def test_crash_before_manifest_keeps_previous_committed(tmp_path):
    """Death between blobs and manifest: the torn attempt holds no commit
    marker and the previous checkpoint restores untouched."""
    tree = {"w": jnp.asarray(_field())}
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    mgr.save(tree, 1)
    with faults.injected(faults.FaultPlan(
            {"ckpt.before_manifest": faults.Fault("crash")})):
        with pytest.raises(faults.InjectedCrash):
            mgr.save(tree, 2)
    assert not (tmp_path / "step_00000002").exists()
    assert (tmp_path / "step_00000002.tmp").is_dir()
    assert not (tmp_path / "step_00000002.tmp" / "manifest.json").exists()
    res = CheckpointManager(str(tmp_path), log=None).restore(
        {"w": jnp.zeros((48, 32), jnp.float32)})
    assert res.step == 1
    assert np.array_equal(np.asarray(res.tree["w"]), _field())


# --------------------------------------------------------------------------
# the train loop's checkpoint ledger (phantom-checkpoint bugfix)
# --------------------------------------------------------------------------

def _toy_state():
    return TrainState(jnp.int32(0),
                      {"w": jnp.zeros((64, 32), jnp.float32)}, None, None)


def _toy_step(state, batch):
    return (state._replace(step=state.step + 1,
                           params={"w": state.params["w"] + 1.0}),
            {"loss": jnp.float32(0.0)})


def _batches():
    while True:
        yield {"x": jnp.zeros(())}


def test_failed_async_write_never_leaves_phantom_checkpoint(tmp_path):
    """The step-4 background write dies; report.checkpoints must list
    only what actually committed and report.failed_checkpoints the rest
    (before the reconcile fix, 4 appeared as a committed checkpoint)."""
    mgr = CheckpointManager(str(tmp_path), async_write=True, log=None,
                            write_retries=0)
    plan = faults.FaultPlan({"ckpt.write": faults.Fault("error", at=4)})
    with faults.injected(plan):
        state, rep = train_loop(_toy_state(), _toy_step, _batches(),
                                num_steps=4, ckpt_manager=mgr,
                                ckpt_every=2, log=lambda *_: None)
    assert rep.checkpoints == [2]
    assert rep.failed_checkpoints == [4]
    assert mgr.latest_step() == 2
    assert rep.steps_run == 4                   # training itself unharmed


def test_failed_write_surfacing_at_next_save_is_resubmitted(tmp_path):
    """A background failure surfaces at the NEXT save's barrier; the loop
    logs it and resubmits the new step on the freed slot, so one bad
    write costs one checkpoint, not two."""
    mgr = CheckpointManager(str(tmp_path), async_write=True, log=None,
                            write_retries=0)
    with faults.injected(faults.FaultPlan(
            {"ckpt.write": faults.Fault("error", at=2)})):
        state, rep = train_loop(_toy_state(), _toy_step, _batches(),
                                num_steps=6, ckpt_manager=mgr,
                                ckpt_every=2, log=lambda *_: None)
    assert rep.checkpoints == [4, 6]
    assert rep.failed_checkpoints == [2]
    assert mgr.latest_step() == 6


def test_prune_skips_the_writer_held_step(tmp_path):
    from repro.ckpt.manager import prune
    for s in (1, 2, 3, 4):
        os.makedirs(tmp_path / f"step_{s:08d}")
    prune(str(tmp_path), keep=1, skip={2})
    left = sorted(p.name for p in tmp_path.iterdir())
    assert left == ["step_00000002", "step_00000004"]


# --------------------------------------------------------------------------
# device loss: mid-run elastic recovery
# --------------------------------------------------------------------------

def test_device_loss_recovery_rolls_back_and_continues(tmp_path):
    """DeviceLoss at step 3: the loop rolls back to the committed step-2
    checkpoint, re-jits, and finishes all 6 steps; params prove the
    rollback really happened (w counts steps since restore)."""
    obs.enable()
    mgr = CheckpointManager(str(tmp_path), async_write=True, log=None)
    plan = faults.FaultPlan(
        {"loop.step": faults.Fault("device_loss", at=3)})
    with faults.injected(plan):
        state, rep = train_loop(_toy_state(), _toy_step, _batches(),
                                num_steps=6, ckpt_manager=mgr,
                                ckpt_every=2, max_recoveries=1,
                                log=lambda *_: None)
    assert len(rep.recoveries) == 1
    ev = rep.recoveries[0]
    assert ev["step"] == 3 and ev["restored_from"] == 2
    assert ev["recovery_s"] > 0
    assert int(state.step) == 6
    assert float(state.params["w"][0, 0]) == 6.0        # 2 kept + 4 replayed
    assert rep.checkpoints == [2, 4, 6]
    assert _counters().get("loop.recoveries") == 1


def test_device_loss_without_recovery_budget_reraises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    with faults.injected(faults.FaultPlan(
            {"loop.step": faults.Fault("device_loss", at=1)})):
        with pytest.raises(DeviceLoss):
            train_loop(_toy_state(), _toy_step, _batches(), num_steps=4,
                       ckpt_manager=mgr, ckpt_every=2, max_recoveries=0,
                       log=lambda *_: None)


def test_device_loss_before_any_checkpoint_fails_loudly(tmp_path):
    """Nothing committed to roll back to: recovery must give up with the
    ORIGINAL DeviceLoss, not loop forever or restart from garbage."""
    mgr = CheckpointManager(str(tmp_path), async_write=False, log=None)
    with faults.injected(faults.FaultPlan(
            {"loop.step": faults.Fault("device_loss", at=1)})):
        with pytest.raises(DeviceLoss):
            train_loop(_toy_state(), _toy_step, _batches(), num_steps=4,
                       ckpt_manager=mgr, ckpt_every=10, max_recoveries=2,
                       log=lambda *_: None)


def test_device_loss_budget_bounds_recovery_attempts(tmp_path):
    """Two losses, budget of one: the first recovers, the second
    re-raises — graceful degradation never becomes an infinite loop."""
    mgr = CheckpointManager(str(tmp_path), async_write=True, log=None)
    with faults.injected(faults.FaultPlan(
            {"loop.step": faults.Fault("device_loss", at=3, times=2)})):
        with pytest.raises(DeviceLoss):
            train_loop(_toy_state(), _toy_step, _batches(), num_steps=6,
                       ckpt_manager=mgr, ckpt_every=2, max_recoveries=1,
                       log=lambda *_: None)


# --------------------------------------------------------------------------
# end to end: 8 fake devices, toposzp checkpoints, device loss mid-run
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_device_loss_mid_run_recovers_onto_rebuilt_mesh():
    """Train on a 4x2 mesh with toposzp checkpoints; lose half the world
    at step 3; the loop rolls back to the committed step-2 checkpoint,
    rebuilds a 2x2 mesh from the 4 survivors, reshards, re-jits via
    rebuild_step, and finishes — with the restored toposzp leaf holding
    the 2*eb bound and zero FP/FT critical points per saved shard."""
    py = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import faults
        from repro.ckpt import CheckpointManager
        from repro.core.critical_points import REGULAR, classify
        from repro.dist.elastic import mesh_shape_dict, rebuild_mesh
        from repro.train import TrainState, train_loop

        mesh1 = rebuild_mesh(jax.devices(), model_parallel=2)
        assert mesh_shape_dict(mesh1) == {'data': 4, 'model': 2}
        rng = np.random.default_rng(0)
        ny, nx = 128, 96
        y, x = np.meshgrid(np.linspace(0, 4*np.pi, ny),
                           np.linspace(0, 4*np.pi, nx), indexing='ij')
        m_host = (np.sin(x)*np.cos(y)
                  + 0.1*rng.standard_normal((ny, nx))).astype(np.float32)

        params = {'m': jax.device_put(jnp.asarray(m_host),
                                      NamedSharding(mesh1, P('data', None))),
                  'n': jnp.zeros((8,), jnp.float32)}
        state = TrainState(jnp.int32(0), params, None, None)

        def step_fn(state, batch):
            # touches 'n' only: 'm' must survive save->loss->restore
            return state._replace(
                step=state.step + 1,
                params={'m': state.params['m'],
                        'n': state.params['n'] + 1.0}), \\
                {'loss': jnp.float32(0.0)}

        def batches():
            while True:
                yield {'x': jnp.zeros(())}

        def rebuild_step(new_mesh):
            return step_fn            # pure jit step: mesh-independent

        eb = 1e-3
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, mode='toposzp', eb=eb, async_write=True,
                                log=None, min_compress_size=1024)
        survivors = jax.devices()[:4]
        plan = faults.FaultPlan(
            {'loop.step': faults.Fault('device_loss', at=3, keep=4)})
        with faults.injected(plan):
            state2, rep = train_loop(state, step_fn, batches(),
                                     num_steps=6, ckpt_manager=mgr,
                                     ckpt_every=2, mesh=mesh1,
                                     model_parallel=2, max_recoveries=1,
                                     rebuild_step=rebuild_step,
                                     log=lambda *_: None)
        assert len(rep.recoveries) == 1, rep.recoveries
        ev = rep.recoveries[0]
        assert ev['step'] == 3 and ev['restored_from'] == 2
        assert ev['mesh'] == {'data': 2, 'model': 2}, ev
        assert ev['devices'] == 4
        assert rep.checkpoints == [2, 4, 6], rep.checkpoints
        assert int(state2.step) == 6
        # resharded onto the rebuilt 2x2 mesh
        assert state2.params['m'].sharding.mesh.devices.size == 4

        # toposzp contract on the leaf that crossed save -> loss -> restore:
        # relaxed 2*eb bound and zero FP/FT per saved shard (4 row blocks)
        out = np.asarray(state2.params['m'])
        assert np.abs(out - m_host).max() <= 2*eb*(1 + 1e-4)
        for rs in range(4):
            blk = slice(rs*ny//4, (rs+1)*ny//4)
            lo = np.asarray(classify(jnp.asarray(m_host[blk])))
            lr = np.asarray(classify(jnp.asarray(out[blk])))
            viol = (lr != REGULAR) & (lr != lo)
            assert not viol.any(), (rs, int(viol.sum()))
        print('FAULT-RECOVERY-OK')
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "FAULT-RECOVERY-OK" in out.stdout
