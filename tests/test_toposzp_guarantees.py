"""TopoSZp system guarantees (paper Sec. III-B, IV-B, Table I/II):

  * |out - orig| <= 2 eps (relaxed-but-strict bound)
  * zero FP and zero FT on every input
  * FN strictly reduced vs plain SZp
  * compression ratio penalty stays bounded
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (false_cases_host, max_abs_error, szp_roundtrip,
                        toposzp_roundtrip)
from repro.core.metrics import psnr

EBS = [1e-2, 1e-3]


@pytest.mark.parametrize("eb", EBS)
def test_relaxed_bound_and_no_fp_ft(smooth_field, eb):
    f = jnp.asarray(smooth_field)
    rec, comp = toposzp_roundtrip(f, eb)
    assert float(max_abs_error(f, rec)) <= 2 * eb * (1 + 1e-5)
    fc = false_cases_host(f, rec)
    assert fc["FP"] == 0 and fc["FT"] == 0


@pytest.mark.parametrize("eb", EBS)
def test_fn_reduction_vs_szp(vortex, eb):
    f = jnp.asarray(vortex)
    rec_szp, _ = szp_roundtrip(f, eb)
    rec_topo, _ = toposzp_roundtrip(f, eb)
    fn_szp = false_cases_host(f, rec_szp)["FN"]
    fn_topo = false_cases_host(f, rec_topo)["FN"]
    if fn_szp > 0:
        assert fn_topo < fn_szp, (fn_topo, fn_szp)
        assert fn_topo <= fn_szp / 2, "expect >=2x fewer FN on smooth data"


def test_noisy_field_still_guaranteed(noisy_field):
    f = jnp.asarray(noisy_field)
    eb = 5e-2
    rec, _ = toposzp_roundtrip(f, eb)
    fc = false_cases_host(f, rec)
    assert fc["FP"] == 0 and fc["FT"] == 0
    assert float(max_abs_error(f, rec)) <= 2 * eb * (1 + 1e-5)


def test_psnr_not_destroyed(smooth_field):
    f = jnp.asarray(smooth_field)
    rec, _ = toposzp_roundtrip(f, 1e-3)
    assert float(psnr(f, rec)) > 50.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 100_000), st.sampled_from([1e-2, 5e-3]))
def test_property_guarantees_random_fields(seed, eb):
    """FP=0, FT=0 and the 2-eps bound on arbitrary random fields."""
    rng = np.random.default_rng(seed)
    ny, nx = rng.integers(8, 48), rng.integers(8, 48)
    kind = seed % 3
    if kind == 0:
        f = rng.standard_normal((ny, nx)).astype(np.float32)
    elif kind == 1:
        y, x = np.meshgrid(np.linspace(0, 6, ny), np.linspace(0, 6, nx),
                           indexing="ij")
        f = (np.sin(x) * np.cos(y)).astype(np.float32)
    else:
        f = (rng.standard_normal((ny, nx)) * 0.01).astype(np.float32)
    f = jnp.asarray(f)
    rec, _ = toposzp_roundtrip(f, eb)
    fc = false_cases_host(f, rec)
    assert fc["FP"] == 0, fc
    assert fc["FT"] == 0, fc
    assert float(max_abs_error(f, rec)) <= 2 * eb * (1 + 1e-4)


def test_rank_order_restored_same_bin():
    """Paper Fig 5: two maxima in one bin keep their order after topo
    reconstruction (the RP metadata at work)."""
    eb = 0.01
    f = np.full((3, 7), 0.0, np.float32)
    f[1, 1] = 0.012   # M1
    f[1, 5] = 0.013   # M2 (same quantization bin as M1 at eps=0.01)
    fj = jnp.asarray(f)
    rec, _ = toposzp_roundtrip(fj, eb)
    assert float(rec[1, 1]) < float(rec[1, 5]), "M1 < M2 ordering lost"
    from repro.core.critical_points import MAXIMA, classify
    lab = classify(rec)
    assert int(lab[1, 1]) == MAXIMA and int(lab[1, 5]) == MAXIMA
