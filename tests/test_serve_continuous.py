"""Continuous-batching serve engine: greedy parity, paged-KV tier
guarantees, scheduler/pool bookkeeping, and the config-tied is_ring fix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm, registry
from repro.models.attention import KVCache
from repro.serve import (ContinuousServeEngine, PagePool, Request, Scheduler,
                         ServeEngine, cache_kind, is_ring, pad_caches)

MAX_LEN = 16


def _smoke_cfg(arch):
    cfg = registry.get_smoke_config(arch)
    if cfg.num_experts:
        # MoE capacity couples rows of a batch; with the default factor the
        # continuous B=num_slots batch drops tokens the B=1 greedy run
        # keeps.  Same precedent as test_models_smoke.
        cfg = cfg.replace(capacity_factor=8.0)
    return cfg


def _requests(cfg, specs):
    """One B=1 request per (prompt_len, max_new_tokens) spec."""
    reqs = []
    for i, (plen, new) in enumerate(specs):
        rng = jax.random.PRNGKey(40 + i)
        if cfg.frontend == "audio_frames":
            inputs = {"embeds": jax.random.normal(
                rng, (1, plen, cfg.d_model), jnp.float32)}
        elif cfg.frontend == "vision_patches":
            npre = cfg.num_prefix_embeds
            inputs = {"patch_embeds": jax.random.normal(
                          rng, (1, npre, cfg.d_model), jnp.float32),
                      "tokens": jax.random.randint(
                          rng, (1, max(plen - npre, 2)), 0,
                          cfg.vocab_size)}
        else:
            inputs = {"tokens": jax.random.randint(rng, (1, plen), 0,
                                                   cfg.vocab_size)}
        reqs.append(Request(rid=i, inputs=inputs, max_new_tokens=new))
    return reqs


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_continuous_raw_matches_greedy(arch):
    """kv_mode="raw" must reproduce the greedy engine bit-for-bit per
    request, across the whole registry (ring caches, recurrent states,
    MoE, audio/vision frontends)."""
    cfg = _smoke_cfg(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(cfg, [(6, 5), (9, 4), (6, 3)])

    eng = ContinuousServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2,
                                page_size=8, kv_mode="raw")
    rep = eng.serve(reqs)
    greedy = ServeEngine(cfg, params, max_len=MAX_LEN)
    for r in reqs:
        want = np.asarray(greedy.generate(r.inputs, r.max_new_tokens))[0]
        got = rep.tokens[r.rid]
        np.testing.assert_array_equal(got, want, err_msg=f"rid {r.rid}")
    assert rep.generated_tokens == sum(n for _, n in [(6, 5), (9, 4), (6, 3)])


def test_eos_evicts_early():
    """A request hitting its eos id frees the slot mid-decode and keeps
    the greedy token prefix."""
    cfg = _smoke_cfg("gemma2_2b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    [req] = _requests(cfg, [(6, 8)])
    greedy = np.asarray(ServeEngine(cfg, params, max_len=MAX_LEN)
                        .generate(req.inputs, 8))[0]
    eos = int(greedy[3])
    req = Request(rid=0, inputs=req.inputs, max_new_tokens=8, eos_id=eos)
    eng = ContinuousServeEngine(cfg, params, max_len=MAX_LEN, num_slots=2,
                                page_size=8, kv_mode="raw")
    rep = eng.serve([req])
    got = rep.tokens[0]
    stop = int(np.argmax(greedy == eos))
    np.testing.assert_array_equal(got, greedy[:stop + 1])
    assert got[-1] == eos


def test_toposzp_pages_keep_guarantees():
    """Every page the tier compresses stays within 2*eb with zero false
    critical points, and bytes go down at peak occupancy."""
    cfg = _smoke_cfg("gemma2_2b").replace(activation_dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = [Request(rid=i, inputs={"tokens": jnp.full((1, 8), 3 + i,
                                                      jnp.int32)},
                    max_new_tokens=20) for i in range(3)]
    eng = ContinuousServeEngine(cfg, params, max_len=32, num_slots=2,
                                page_size=8, kv_mode="toposzp", kv_eb=0.16,
                                verify_guarantees=True)
    rep = eng.serve(reqs)
    st = rep.pool_stats
    assert st["pages_compressed"] > 0
    assert st["fields_verified"] > 0
    assert st["max_abs_err"] <= 2 * 0.16
    assert st["false_critical_points"] == 0
    peak = max(rep.kv_samples, key=lambda s: s["raw_equiv_bytes"])
    assert peak["cold_pages"] > 0
    assert peak["resident_bytes"] < peak["raw_equiv_bytes"]


def _filled_pool_caches(cfg, pool, num_slots, max_len):
    """Rowwise serve caches with seeded random KV contents."""
    caches = lm.rowwise_caches(
        pad_caches(lm.make_caches(cfg, num_slots, max_len), max_len))

    def fill(path_i, c):
        if not isinstance(c, KVCache):
            return c
        kk = jax.random.normal(jax.random.PRNGKey(path_i[0]), c.k.shape,
                               jnp.float32).astype(c.k.dtype)
        vv = jax.random.normal(jax.random.PRNGKey(path_i[0] + 100),
                               c.v.shape, jnp.float32).astype(c.v.dtype)
        return c._replace(k=kk, v=vv)

    gcaches, tcaches = caches
    if gcaches is not None:
        gcaches = tuple(fill((i,), c) for i, c in enumerate(gcaches))
    tcaches = [fill((50 + j,), c) for j, c in enumerate(tcaches)]
    return gcaches, tcaches


def test_pagepool_fetch_matches_materialized():
    """fetch_page (the on-demand store read) is bit-identical to the
    reconstruction compress_pages materialized into the caches, and
    release_slot drops streams with refcounting."""
    cfg = _smoke_cfg("gemma2_2b").replace(activation_dtype=jnp.float32)
    pool = PagePool(cfg, num_slots=2, max_len=32, page_size=8,
                    kv_mode="toposzp", eb=0.1, verify=True)
    caches = _filled_pool_caches(cfg, pool, 2, 32)
    orig = caches
    pages = [(0, 0), (0, 1), (1, 0)]
    caches = pool.compress_pages(caches, pages)

    for slot, page in pages:
        fetched = np.asarray(pool.fetch_page(slot, page))
        lo = page * pool.page_size
        li = 0
        for which, i, g in pool.layers:
            for fi, name in enumerate(("k", "v")):
                arr = pool._layer_array(caches, which, i, g, name)
                region = np.asarray(arr[slot, lo:lo + pool.page_size],
                                    np.float32)
                np.testing.assert_array_equal(fetched[li + fi], region)
                before = np.asarray(
                    pool._layer_array(orig, which, i, g, name)
                    [slot, lo:lo + pool.page_size], np.float32)
                assert np.abs(region - before).max() <= 2 * 0.1 + 1e-6
            li += 2
    assert pool.stats["false_critical_points"] == 0
    assert pool.stats["fields_verified"] == 3 * pool.fields_per_page

    acct = pool.kv_bytes({0: 32, 1: 16})
    assert acct["occupied_pages"] == 6 and acct["cold_pages"] == 3
    assert acct["resident_bytes"] < acct["raw_equiv_bytes"]

    pool.release_slot(0)
    assert (1, 0) in pool._compressed and (0, 0) not in pool._compressed
    pool.fetch_page(1, 0)                       # shared call still alive
    pool.release_slot(1)
    assert not pool._compressed and not pool._calls


def test_pagepool_cold_page_state():
    cfg = _smoke_cfg("gemma2_2b")
    pool = PagePool(cfg, num_slots=2, max_len=32, page_size=8,
                    kv_mode="szp", cold_after=2)
    # write head at 19: pages 0,1 fully >= 2 steps behind; page 2 partial
    assert pool.cold_pages({0: 19}) == [(0, 0), (0, 1)]
    assert pool.occupied_pages(19) == 3
    pool._compressed[(0, 0)] = {"call": 0, "offset": 0, "bytes": 1}
    pool._calls[0] = {"comp": None, "pages": [(0, 0)], "refs": 1}
    assert pool.cold_pages({0: 19}) == [(0, 1)]
    with pytest.raises(ValueError):
        PagePool(cfg, 2, 30, 8)                 # max_len % page_size != 0
    with pytest.raises(ValueError):
        PagePool(cfg, 2, 32, 8, kv_mode="zip")


def test_scheduler_fifo_and_eviction():
    sched = Scheduler(num_slots=2)
    reqs = [Request(rid=i, inputs={}, max_new_tokens=2 + i)
            for i in range(4)]
    for r in reqs:
        sched.add(r)
    admitted = sched.admit(0, lambda r: 4)
    assert [st.req.rid for st in admitted] == [0, 1]
    assert sched.free_slots() == [] and len(sched.waiting) == 2
    sched.active[0].tokens.extend([7, 7])       # rid 0 hits its budget
    done = sched.evict_finished(3)
    assert [st.req.rid for st in done] == [0]
    assert done[0].finish_step == 3
    assert sched.free_slots() == [0]
    admitted = sched.admit(4, lambda r: 4)      # FIFO: rid 2 takes slot 0
    assert [st.req.rid for st in admitted] == [2]
    assert sched.positions() == {0: 4, 1: 4}    # pre-first-token heads
    assert sched.has_work()


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_is_ring_matches_cache_shapes(arch):
    """is_ring/cache_kind agree with the caches make_caches actually
    builds, for every layer kind of every registered arch."""
    cfg = registry.get_smoke_config(arch)
    gcaches, tcaches = lm.make_caches(cfg, 1, 64)
    groups, tail = cfg.pattern_layers()

    def check(kind, cache):
        if isinstance(cache, KVCache):
            ring = cache.k.shape[-3] < 64
            assert is_ring(cfg, kind) == ring, (arch, kind)
            assert cache_kind(cfg, kind) == ("ring" if ring else "full")
        else:
            assert cache_kind(cfg, kind) == "recurrent", (arch, kind)
            assert not is_ring(cfg, kind)

    if groups:
        for i, kind in enumerate(cfg.layer_pattern):
            check(kind, gcaches[i])
    for j, kind in enumerate(tail):
        check(kind, tcaches[j])


def test_is_ring_follows_config_not_kind_string():
    """The old is_ring ignored cfg; a 'local' layer with no window under
    the config must report as a full cache."""
    cfg = registry.get_smoke_config("gemma2_2b")
    assert is_ring(cfg, "local")
    assert not is_ring(cfg.replace(window_size=None), "local")
    with pytest.raises(KeyError):
        cache_kind(cfg, "hyena")


def test_rowwise_cache_parity_and_idempotence():
    """Per-row positions change nothing about the decode math: shared- and
    rowwise-cache decode logits agree bitwise."""
    cfg = _smoke_cfg("gemma2_2b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                              cfg.vocab_size)
    _, caches = lm.prefill(params, cfg, {"tokens": toks})
    shared = pad_caches(caches, MAX_LEN)
    rowwise = lm.rowwise_caches(shared)
    assert jax.tree.all(jax.tree.map(
        jnp.array_equal, lm.rowwise_caches(rowwise), rowwise))

    tok_s = tok_r = jnp.full((2, 1), 3, jnp.int32)
    for _ in range(3):
        tok_s, log_s, shared = lm.decode_step(params, cfg, tok_s, shared)
        tok_r, log_r, rowwise = lm.decode_step(params, cfg, tok_r, rowwise)
        np.testing.assert_array_equal(np.asarray(log_s), np.asarray(log_r))
