"""Minimal deterministic fallback for the ``hypothesis`` library.

CI has no network access, so ``hypothesis`` may be missing.  Test modules
import it as

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

When the real library is present it is used unchanged.  This shim keeps
the property-test *shape* but expands each strategy into a small fixed
example set: ``@given(s1, s2, ...)`` turns the test into a loop over
``min(max_examples, 10)`` deterministic draws (each strategy draws from a
seeded-per-index RNG), so the tests still sweep a spread of inputs and
failures reproduce exactly.  Only the strategy surface this repo uses is
implemented: integers / floats / sampled_from / lists.
"""
from __future__ import annotations

import functools
import math
from typing import Any, List

import numpy as np

_DEFAULT_EXAMPLES = 10   # cap: deterministic shim trades volume for speed


class _Strategy:
    """A deterministic example generator: draw(i) -> i-th example."""

    def draw(self, i: int, rng: np.random.Generator) -> Any:
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def draw(self, i, rng):
        edges = [self.lo, self.hi, (self.lo + self.hi) // 2]
        if i < len(edges):
            return edges[i]
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float, **_kw):
        self.lo, self.hi = float(lo), float(hi)

    def draw(self, i, rng):
        edges = [self.lo, self.hi, 0.0 if self.lo <= 0.0 <= self.hi
                 else 0.5 * (self.lo + self.hi)]
        if i < len(edges):
            return np.float32(edges[i]).item()
        u = rng.random()
        return np.float32(self.lo + u * (self.hi - self.lo)).item()


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def draw(self, i, rng):
        if i < len(self.options):
            return self.options[i]
        return self.options[int(rng.integers(len(self.options)))]


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int = 0,
                 max_size: int = 10):
        self.elem = elem
        self.min_size, self.max_size = int(min_size), int(max_size)

    def draw(self, i, rng):
        sizes = [self.min_size, self.max_size,
                 (self.min_size + self.max_size) // 2]
        n = (sizes[i] if i < len(sizes)
             else int(rng.integers(self.min_size, self.max_size + 1)))
        return [self.elem.draw(int(rng.integers(1000)), rng)
                for _ in range(max(n, self.min_size))]


class _StrategiesModule:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float, **kw) -> _Strategy:
        return _Floats(min_value, max_value, **kw)

    @staticmethod
    def sampled_from(options) -> _Strategy:
        return _SampledFrom(options)

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Lists(elem, min_size=min_size, max_size=max_size)


strategies = _StrategiesModule()
st = strategies


def given(*strats: _Strategy):
    """Expand the strategies into a deterministic example loop."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                    _DEFAULT_EXAMPLES)
            for i in range(n):
                rng = np.random.default_rng([i, len(strats), 0xC0FFEE])
                drawn = [s.draw(i, rng) for s in strats]
                try:
                    fn(*args, *drawn, **kw)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}") from e
            return None
        wrapper._hypothesis_shim = True
        # hide the strategy parameters from pytest's fixture resolution
        # (inspect.signature would otherwise follow __wrapped__)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    """Record max_examples on the (already-)wrapped test; ignore the rest
    (deadline etc. have no meaning for the deterministic shim)."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
