"""dist.ring coverage: bitpacked ring all-reduce equivalence with the int32
psum path (single-device fast + 8-fake-device subprocess), wire accounting,
and the packed-format validation errors."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.quantize import quantize
from repro.dist.collectives import (_leaf_eb, compressed_psum_tree,
                                    topo_compressed_psum_tree)
from repro.dist.compat import shard_map
from repro.dist.ring import (base_width, packed_wire_summary, ring_perm,
                             simulate_hop_bytes)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_tree(wire_format, topo_frac, g, err=None, rel_eb=1e-3):
    """One-device shard_map run of the (topo_)compressed psum tree."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))

    def f(gs):
        gl = gs.reshape(-1)
        tree = {"a": gl[: gl.shape[0] // 2], "b": gl[gl.shape[0] // 2:]}
        e = None if err is None else jax.tree.map(jnp.zeros_like, tree)
        if topo_frac > 0:
            gbar, new_e = topo_compressed_psum_tree(
                tree, "data", rel_eb=rel_eb, topo_frac=topo_frac, err=e,
                wire_format=wire_format)
        else:
            gbar, new_e = compressed_psum_tree(tree, "data", rel_eb=rel_eb,
                                               err=e,
                                               wire_format=wire_format)
        return gbar["a"], gbar["b"], new_e["a"], new_e["b"]

    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                             out_specs=(P(), P(), P(), P()),
                             check_vma=False))(g.reshape(1, -1))


@pytest.mark.parametrize("topo_frac", [0.0, 1e-2])
def test_packed_matches_int32_single_device(topo_frac):
    """Full shard_map path on one device: the packed ring must reproduce
    the int32 psum path bit-for-bit (gradients AND error feedback)."""
    rng = np.random.default_rng(0)
    g = (rng.standard_normal(5000) * 1e-3).astype(np.float32)
    g[:32] *= 100.0
    ref = _run_tree("int32", topo_frac, jnp.asarray(g), err=True)
    got = _run_tree("packed", topo_frac, jnp.asarray(g), err=True)
    for r, o in zip(ref, got):
        assert np.array_equal(np.asarray(r), np.asarray(o))


def test_ring_perm_is_unidirectional_cycle():
    assert ring_perm(4) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert ring_perm(1) == [(0, 0)]


def test_base_width_static_bound():
    """Every realizable code magnitude fits base_width bits."""
    rng = np.random.default_rng(0)
    for rel_eb in (1e-1, 1e-2, 1e-3, 1e-4):
        x = jnp.asarray((rng.standard_normal(4096) * 7.7).astype(np.float32))
        q = quantize(x, _leaf_eb(x, rel_eb))
        assert int(jnp.abs(q).max()) < 2 ** base_width(rel_eb)


def test_simulate_hop_bytes_beats_int32():
    """Measured packed bytes/hop on gradient-shaped codes stay well under
    the int32 wire at rel_eb=1e-2 (the bench regression gate's claim)."""
    rng = np.random.default_rng(0)
    g = (rng.standard_normal((8, 1 << 14)) * 1e-3).astype(np.float32)
    g[:, :50] *= 100.0
    gj = jnp.asarray(g)
    qs = quantize(gj, _leaf_eb(gj, 1e-2))
    rec = simulate_hop_bytes(qs, 1e-2)
    assert rec["hops"] == 7
    assert rec["valid_vs_int32"] <= rec["shipped_vs_int32"]
    assert rec["shipped_vs_int32"] < 0.55
    assert rec["valid_bytes_per_hop"] <= rec["shipped_bytes_per_hop"]


def test_packed_wire_summary_accounting():
    """Static wire model: per-hop growth, bucketing, sidecar terms."""
    rec = packed_wire_summary([1 << 16, 100, 3], rel_eb=1e-2,
                              topo_frac=1e-3, n_members=8)
    assert rec["hops"] == 7
    assert rec["base_width_bits"] == base_width(1e-2)
    assert len(rec["packed_hop_bytes"]) == 7
    # widths (and so hop bytes) grow monotonically along the ring
    assert rec["packed_hop_bytes"] == sorted(rec["packed_hop_bytes"])
    assert rec["packed_vs_int32_per_hop"] < 0.55
    assert rec["packed_bytes_per_step"] >= sum(rec["packed_hop_bytes"])
    # one member: nothing moves
    rec1 = packed_wire_summary([1 << 16], 1e-2, 0.0, 1)
    assert rec1["hops"] == 0 and rec1["packed_bytes_per_step"] == 0.0


def test_packed_requires_single_axis():
    from repro.dist.ring import _require_single_axis
    with pytest.raises(NotImplementedError, match="ONE"):
        _require_single_axis(("pod", "data"))
    assert _require_single_axis(("data",)) == "data"


def test_packed_rejects_overflowing_rel_eb():
    """The ring accumulates in int32 sign-magnitude: n * max_code over
    int32 must raise a clear trace-time error, not wrap."""
    g = jnp.ones((64,), jnp.float32)
    with pytest.raises(ValueError, match="int32"):
        _run_tree("packed", 0.0, g, rel_eb=1e-10)


def test_make_train_step_wire_format_validation():
    from repro.models import registry
    from repro.optim import adamw, constant
    from repro.train import make_train_step

    cfg = registry.get_smoke_config("gemma2_2b")
    opt = adamw(constant(1e-3))
    with pytest.raises(ValueError, match="grad_compress"):
        make_train_step(cfg, opt, wire_format="packed")
    with pytest.raises(ValueError, match="wire_format"):
        make_train_step(cfg, opt, wire_format="zstd")
    # config knob wires through the same validation
    with pytest.raises(ValueError, match="grad_compress"):
        make_train_step(cfg.replace(grad_wire_format="packed"), opt)


@pytest.mark.slow
def test_packed_ring_bit_identical_multi_device():
    """8 fake devices: the packed ring all-reduce must equal the int32
    psum path bit-for-bit — mean gradient, error-feedback tree — and
    protected entries must still be the exact fp32 psum mean."""
    py = textwrap.dedent("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.collectives import protect_k, topo_compressed_psum_tree
        from repro.dist.compat import shard_map

        n, size, topo_frac = 8, 5000, 1e-2
        rng = np.random.default_rng(0)
        x = (rng.standard_normal((n, size)) * 1e-3).astype(np.float32)
        x[:, :32] *= 100.0
        mesh = Mesh(np.array(jax.devices()[:n]), ('data',))

        def make(wire):
            def f(xs):
                gl = xs.reshape(-1)
                tree = {'a': gl[:3000].reshape(30, 100), 'b': gl[3000:]}
                err = jax.tree.map(jnp.zeros_like, tree)
                gbar, new_e = topo_compressed_psum_tree(
                    tree, 'data', rel_eb=1e-3, topo_frac=topo_frac,
                    err=err, wire_format=wire)
                return gbar['a'], gbar['b'], new_e['a'], new_e['b']
            return jax.jit(shard_map(f, mesh=mesh, in_specs=P('data'),
                                     out_specs=(P(), P(), P('data'),
                                                P('data')),
                                     check_vma=False))

        ref = make('int32')(jnp.asarray(x))
        got = make('packed')(jnp.asarray(x))
        for name, r, o in zip(('ga', 'gb', 'ea', 'eb'), ref, got):
            assert np.array_equal(np.asarray(r), np.asarray(o)), name

        # protected entries: exact fp32 psum mean (reference reduction)
        def ref_mean(xs):
            return jax.lax.psum(xs.reshape(-1), 'data') / n
        exact = np.asarray(jax.jit(shard_map(
            ref_mean, mesh=mesh, in_specs=P('data'), out_specs=P(),
            check_vma=False))(jnp.asarray(x)))
        gbar = np.concatenate([np.asarray(got[0]).reshape(-1),
                               np.asarray(got[1])])
        for lo, hi in ((0, 3000), (3000, 5000)):
            k = protect_k(hi - lo, topo_frac)
            union = np.unique(
                np.argsort(-np.abs(x[:, lo:hi]), axis=1)[:, :k]) + lo
            assert np.array_equal(gbar[union], exact[union]), (lo, hi)
        print('PACKED-RING-IDENTICAL-OK')
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "PACKED-RING-IDENTICAL-OK" in out.stdout


@pytest.mark.slow
def test_psum_leaf_widens_at_tiny_rel_eb_multi_device():
    """8 members x code 5e8 = 4e9 > int32: the int32 wire format must
    widen the psum (hi/lo split) instead of silently wrapping."""
    py = textwrap.dedent("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.collectives import compressed_psum_tree
        from repro.dist.compat import shard_map

        n = 8
        mesh = Mesh(np.array(jax.devices()[:n]), ('data',))

        def f(xs):
            gbar, _ = compressed_psum_tree({'g': xs.reshape(-1)}, 'data',
                                           rel_eb=1e-9)
            return gbar['g']
        gbar = np.asarray(jax.jit(shard_map(
            f, mesh=mesh, in_specs=P('data'), out_specs=P(),
            check_vma=False))(jnp.full((n, 64), 0.5, jnp.float32)))
        # pre-fix the wrapped sum gives ~-0.037; widened it is ~0.5
        assert np.abs(gbar - 0.5).max() < 1e-4, gbar[:4]
        print('WIDENED-PSUM-OK')
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "WIDENED-PSUM-OK" in out.stdout
