"""Device-residency regression tests for the resident compression core.

Three layers of proof that the jitted round-trip never touches the host:

  * ``jax.transfer_guard("disallow")`` around the already-compiled calls —
    any implicit host->device transfer (a Python scalar or numpy array
    sneaking into the graph) raises.
  * Whole compress -> decompress round-trips traced under ONE enclosing
    ``jax.jit`` — any ``int(np.asarray(tracer))`` host sync fails at trace
    time, which is the strongest structural zero-sync proof available on
    CPU (where device->host reads are zero-copy and guard-invisible).
  * Byte-parity: the resident (``lax.switch``-packed, worst-case-padded)
    streams serialize to EXACTLY the classic two-pass streams.

Plus compaction-kernel-vs-jnp-oracle parity across odd block counts and
degenerate width distributions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitpack
from repro.core import io as cio
from repro.core.szp import (szp_compress, szp_compress_batch, szp_decompress,
                            szp_decompress_batch, tri_guard_width)
from repro.core.toposzp import (batch_slice, toposzp_compress,
                                toposzp_compress_batch, toposzp_decompress)
from repro.kernels import ops

EB = 1e-3
BACKENDS = ("jnp", "interpret")


def _field(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# --------------------------------------------------------------------------
# Compaction kernel vs jnp oracle
# --------------------------------------------------------------------------

def _local_blocks(b, k, widths, seed=0):
    """Phase-1 local pack for ``b`` blocks with the given width per block."""
    rng = np.random.default_rng(seed)
    mags = np.stack([
        rng.integers(0, 1 << w, size=k).astype(np.uint32)
        if w else np.zeros(k, np.uint32) for w in widths])
    mags = jnp.asarray(mags)
    wid = jnp.asarray(np.asarray(widths, np.uint8))
    local = ops.local_pack(mags, wid, max_width=bitpack.MAX_WIDTH,
                           backend="jnp")
    return local, wid


@pytest.mark.parametrize("b", [1, 5, 31, 100, 129, 257])
def test_compact_kernel_matches_oracle_odd_sizes(b):
    k = 31
    rng = np.random.default_rng(b)
    widths = rng.integers(0, bitpack.MAX_WIDTH + 1, size=b)
    local, wid = _local_blocks(b, k, widths, seed=b)
    ref_buf, ref_offs, ref_total = bitpack.compact_local_bytes(local, wid, k)
    buf, offs, total = ops.compact_bytes(local, wid, k, backend="interpret")
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(ref_buf))
    np.testing.assert_array_equal(np.asarray(offs), np.asarray(ref_offs))
    assert int(total) == int(ref_total)


@pytest.mark.parametrize("widths_kind", ["all_zero", "all_max", "spiky"])
def test_compact_kernel_degenerate_width_distributions(widths_kind):
    b, k = 64, 31
    if widths_kind == "all_zero":
        widths = np.zeros(b, np.int64)
    elif widths_kind == "all_max":
        widths = np.full(b, bitpack.MAX_WIDTH)
    else:  # one wide block in a sea of constants
        widths = np.zeros(b, np.int64)
        widths[b // 2] = bitpack.MAX_WIDTH
    local, wid = _local_blocks(b, k, widths, seed=3)
    ref_buf, _, ref_total = bitpack.compact_local_bytes(local, wid, k)
    buf, _, total = ops.compact_bytes(local, wid, k, backend="interpret")
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(ref_buf))
    assert int(total) == int(ref_total)


# --------------------------------------------------------------------------
# Resident == classic byte parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_szp_resident_serializes_identically(backend):
    x = _field((48, 96), seed=1)
    classic = cio.serialize_szp(szp_compress(x, EB, backend=backend),
                                x.shape, EB)
    resident = cio.serialize_szp(
        szp_compress(x, EB, backend=backend, resident=True), x.shape, EB)
    assert resident == classic


@pytest.mark.parametrize("backend", BACKENDS)
def test_szp_resident_batch_serializes_identically(backend):
    xs = jnp.stack([_field((32, 64), seed=s, scale=10.0 ** (s % 3))
                    for s in range(4)])
    classic = szp_compress_batch(xs, EB, backend=backend)
    resident = szp_compress_batch(xs, EB, backend=backend, resident=True)
    for i in range(xs.shape[0]):
        sl = lambda p: jax.tree_util.tree_map(lambda a: a[i], p)
        assert (cio.serialize_szp(sl(resident), xs.shape[1:], EB)
                == cio.serialize_szp(sl(classic), xs.shape[1:], EB))


@pytest.mark.parametrize("backend", BACKENDS)
def test_toposzp_resident_serializes_identically(backend):
    x = _field((40, 64), seed=2)
    classic = cio.serialize_toposzp(
        toposzp_compress(x, EB, backend=backend), x.shape, EB)
    resident = cio.serialize_toposzp(
        toposzp_compress(x, EB, backend=backend, resident=True), x.shape, EB)
    assert resident == classic


@pytest.mark.parametrize("backend", BACKENDS)
def test_toposzp_resident_batch_serializes_identically(backend):
    xs = jnp.stack([_field((32, 64), seed=10 + s) for s in range(3)])
    classic = toposzp_compress_batch(xs, EB, backend=backend)
    resident = toposzp_compress_batch(xs, EB, backend=backend, resident=True)
    for i in range(xs.shape[0]):
        assert (cio.serialize_toposzp(batch_slice(resident, i),
                                      xs.shape[1:], EB)
                == cio.serialize_toposzp(batch_slice(classic, i),
                                         xs.shape[1:], EB))


# --------------------------------------------------------------------------
# Transfer-guard: jitted round-trip with zero implicit transfers
# --------------------------------------------------------------------------

def test_szp_roundtrip_under_transfer_guard():
    x = _field((64, 128), seed=4)
    eb = jnp.float32(EB)            # pre-placed: a Python float would h2d
    # warm-up compile outside the guard (compilation may transfer consts)
    parts = szp_compress(x, eb, resident=True, backend="jnp")
    szp_decompress(parts, x.shape, eb, backend="jnp").block_until_ready()
    with jax.transfer_guard("disallow"):
        parts = szp_compress(x, eb, resident=True, backend="jnp")
        out = szp_decompress(parts, x.shape, eb, backend="jnp")
        out.block_until_ready()
    assert float(jnp.abs(out - x).max()) <= EB + 1e-7


def test_szp_batch_roundtrip_under_transfer_guard():
    xs = jnp.stack([_field((32, 64), seed=20 + s) for s in range(3)])
    eb = jnp.float32(EB)
    parts = szp_compress_batch(xs, eb, resident=True, backend="jnp")
    szp_decompress_batch(parts, xs.shape[1:], eb,
                         backend="jnp").block_until_ready()
    with jax.transfer_guard("disallow"):
        parts = szp_compress_batch(xs, eb, resident=True, backend="jnp")
        outs = szp_decompress_batch(parts, xs.shape[1:], eb, backend="jnp")
        outs.block_until_ready()
    assert float(jnp.abs(outs - xs).max()) <= EB + 1e-7


def test_toposzp_compress_under_transfer_guard():
    x = _field((48, 64), seed=5)
    eb = jnp.float32(EB)
    toposzp_compress(x, eb, resident=True,
                     backend="jnp").szp.payload.block_until_ready()
    with jax.transfer_guard("disallow"):
        comp = toposzp_compress(x, eb, resident=True, backend="jnp")
        comp.szp.payload.block_until_ready()
    out = toposzp_decompress(comp, x.shape, eb, backend="jnp")
    assert float(jnp.abs(out - x).max()) <= 2 * EB + 1e-7


# --------------------------------------------------------------------------
# Structural zero-sync proof: the whole round-trip traces under ONE jit
# --------------------------------------------------------------------------

def test_roundtrip_traces_under_single_jit():
    """Compress -> decompress as one jitted function: any hidden host sync
    (``int(np.asarray(tracer))``) would raise a TracerError here."""
    x = _field((64, 96), seed=6)

    @jax.jit
    def roundtrip(x, eb):
        parts = szp_compress(x, eb, resident=True, backend="jnp")
        return szp_decompress(parts, x.shape, eb, backend="jnp"), parts.nbytes

    out, nbytes = roundtrip(x, jnp.float32(EB))
    assert float(jnp.abs(out - x).max()) <= EB + 1e-7
    assert int(nbytes) > 0


def test_batch_roundtrip_traces_under_single_jit():
    xs = jnp.stack([_field((32, 64), seed=30 + s) for s in range(3)])

    @jax.jit
    def roundtrip(xs, eb):
        parts = szp_compress_batch(xs, eb, resident=True, backend="jnp")
        return szp_decompress_batch(parts, xs.shape[1:], eb, backend="jnp")

    outs = roundtrip(xs, jnp.float32(EB))
    assert float(jnp.abs(outs - xs).max()) <= EB + 1e-7


def test_resident_guard_picks_exact_path_for_wide_blocks():
    """Fields whose widths cross the 2^24 tri-matmul limit must flip the
    in-graph ``lax.cond`` to the exact int32-cumsum dequant: the guarded
    backend output must match the always-exact jnp dequant bit-for-bit."""
    block = 32
    assert tri_guard_width(block) <= bitpack.MAX_WIDTH
    x = _field((32, 64), seed=7, scale=1e3)   # codes ~1e7: past the guard
    eb = jnp.float32(1e-4)
    parts = szp_compress(x, eb, resident=True, backend="interpret")
    assert int(np.asarray(parts.widths).max()) >= tri_guard_width(block)

    @jax.jit
    def dec(parts, eb):
        return szp_decompress(parts, x.shape, eb, backend="interpret")

    guarded = dec(parts, eb)
    exact = szp_decompress(parts, x.shape, eb, backend="jnp")
    np.testing.assert_array_equal(np.asarray(guarded), np.asarray(exact))


def test_donated_compress_matches_undonated():
    x = _field((48, 64), seed=8)
    keep = cio.serialize_szp(szp_compress(x, EB, resident=True,
                                          backend="jnp"), x.shape, EB)
    xd = jnp.array(x)   # fresh buffer to donate
    don = cio.serialize_szp(szp_compress(xd, EB, resident=True, donate=True,
                                         backend="jnp"), x.shape, EB)
    assert don == keep
