"""Bit-packing exactness: pack/unpack roundtrips over width sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import bitpack


@pytest.mark.parametrize("k", [8, 31, 32])
@pytest.mark.parametrize("seed", [0, 1])
def test_pack_unpack_roundtrip(k, seed):
    rng = np.random.default_rng(seed)
    b = 64
    widths = rng.integers(0, 33, b).astype(np.int32)
    mags = np.zeros((b, k), np.uint32)
    for i, w in enumerate(widths):
        if w > 0:
            mags[i] = rng.integers(0, 2 ** min(int(w), 32), k, dtype=np.uint64)
    buf, offs, total = bitpack.pack_blocks(jnp.asarray(mags),
                                           jnp.asarray(widths))
    out = bitpack.unpack_blocks(buf, jnp.asarray(widths), k)
    assert np.array_equal(np.asarray(out), mags)
    # compressed size matches the width accounting exactly
    expect = int(sum((k * int(w) + 7) // 8 for w in widths))
    assert int(total) == expect


def test_zero_width_blocks_cost_nothing():
    b, k = 16, 31
    mags = jnp.zeros((b, k), jnp.uint32)
    widths = jnp.zeros((b,), jnp.int32)
    _, _, total = bitpack.pack_blocks(mags, widths)
    assert int(total) == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 255), st.integers(1, 64))
def test_bits_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    packed = bitpack.pack_bits(jnp.asarray(bits))
    out = bitpack.unpack_bits(packed, n)
    assert np.array_equal(np.asarray(out), bits)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 255), st.integers(1, 64))
def test_2bit_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 4, n).astype(np.int32)
    packed = bitpack.pack_2bit(jnp.asarray(vals))
    out = bitpack.unpack_2bit(packed, n)
    assert np.array_equal(np.asarray(out), vals)
