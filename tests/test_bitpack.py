"""Bit-packing exactness: pack/unpack roundtrips over width sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # no network in CI: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import bitpack


@pytest.mark.parametrize("k", [8, 31, 32])
@pytest.mark.parametrize("seed", [0, 1])
def test_pack_unpack_roundtrip(k, seed):
    rng = np.random.default_rng(seed)
    b = 64
    widths = rng.integers(0, 33, b).astype(np.int32)
    mags = np.zeros((b, k), np.uint32)
    for i, w in enumerate(widths):
        if w > 0:
            mags[i] = rng.integers(0, 2 ** min(int(w), 32), k, dtype=np.uint64)
    buf, offs, total = bitpack.pack_blocks(jnp.asarray(mags),
                                           jnp.asarray(widths))
    out = bitpack.unpack_blocks(buf, jnp.asarray(widths), k)
    assert np.array_equal(np.asarray(out), mags)
    # compressed size matches the width accounting exactly
    expect = int(sum((k * int(w) + 7) // 8 for w in widths))
    assert int(total) == expect


def test_zero_width_blocks_cost_nothing():
    b, k = 16, 31
    mags = jnp.zeros((b, k), jnp.uint32)
    widths = jnp.zeros((b,), jnp.int32)
    _, _, total = bitpack.pack_blocks(mags, widths)
    assert int(total) == 0


def test_zero_width_adjacent_to_nonzero_blocks():
    """Zero-width blocks between nonzero ones duplicate byte offsets; the
    searchsorted byte->block map must hand those bytes to the LAST block
    at the offset (side='right'), not the empty one."""
    k = 16
    widths = np.array([3, 0, 0, 5, 0, 2, 0], np.int32)
    rng = np.random.default_rng(0)
    mags = np.zeros((widths.size, k), np.uint32)
    for i, w in enumerate(widths):
        if w > 0:
            mags[i] = rng.integers(0, 2 ** int(w), k)
    buf, offs, total = bitpack.pack_blocks(jnp.asarray(mags),
                                           jnp.asarray(widths))
    # duplicate offsets exist (the degenerate case under test)
    assert len(set(np.asarray(offs).tolist())) < widths.size
    out = bitpack.unpack_blocks(buf, jnp.asarray(widths), k)
    assert np.array_equal(np.asarray(out), mags)
    assert int(total) == sum((k * int(w) + 7) // 8 for w in widths)


def test_full_width_32_mask_path():
    """w=32 blocks exercise the mask-everything branch (1<<32 would wrap)."""
    k = 8
    rng = np.random.default_rng(1)
    mags = rng.integers(0, 2 ** 32, (4, k), dtype=np.uint64).astype(np.uint32)
    mags[0, 0] = 0xFFFFFFFF
    widths = jnp.full((4,), 32, jnp.int32)
    buf, _, total = bitpack.pack_blocks(jnp.asarray(mags), widths)
    out = bitpack.unpack_blocks(buf, widths, k)
    assert np.array_equal(np.asarray(out), mags)
    assert int(total) == 4 * 4 * k


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 33), st.integers(1, 12))
def test_property_pack_unpack_roundtrip(seed, k, b):
    """unpack_blocks(pack_blocks(m, w)) == m for arbitrary widths 0..32."""
    rng = np.random.default_rng(seed)
    widths = rng.integers(0, 33, b).astype(np.int32)
    mags = np.zeros((b, k), np.uint32)
    for i, w in enumerate(widths):
        if w > 0:
            mags[i] = rng.integers(0, 2 ** min(int(w), 32), k,
                                   dtype=np.uint64)
    buf, _, _ = bitpack.pack_blocks(jnp.asarray(mags), jnp.asarray(widths))
    out = bitpack.unpack_blocks(buf, jnp.asarray(widths), k)
    assert np.array_equal(np.asarray(out), mags)


@pytest.mark.parametrize("max_width", [1, 7, 11, 32])
def test_pack_blocks_static_width_cap(max_width):
    """The ring's static cap shrinks the shipped buffer without changing
    the packed bytes: capped pack == full pack's valid prefix."""
    k, b = 32, 9
    rng = np.random.default_rng(max_width)
    widths = rng.integers(0, max_width + 1, b).astype(np.int32)
    mags = np.zeros((b, k), np.uint32)
    for i, w in enumerate(widths):
        if w > 0:
            mags[i] = rng.integers(0, 2 ** int(w), k)
    full, _, total = bitpack.pack_blocks(jnp.asarray(mags),
                                         jnp.asarray(widths))
    capped, _, total2 = bitpack.pack_blocks(jnp.asarray(mags),
                                            jnp.asarray(widths),
                                            max_width=max_width)
    assert int(total) == int(total2)
    assert capped.shape[0] == b * ((k * max_width + 7) // 8)
    assert capped.shape[0] <= full.shape[0]
    assert np.array_equal(np.asarray(full)[:int(total)],
                          np.asarray(capped)[:int(total)])
    out = bitpack.unpack_blocks(capped, jnp.asarray(widths), k)
    assert np.array_equal(np.asarray(out), mags)


@pytest.mark.parametrize("k", [8, 31, 32])
@pytest.mark.parametrize("max_width", [1, 2, 4, 8, 16, 32])
def test_tiled_pack_equals_worstcase_prefix(k, max_width):
    """pack_blocks_tiled == pack_blocks's valid prefix at every capacity
    bucket, with the shrunk cap B*ceil(K*mw/8); roundtrip stays exact."""
    rng = np.random.default_rng(k * max_width)
    b = 23
    widths = rng.integers(0, max_width + 1, b).astype(np.int32)
    mags = np.zeros((b, k), np.uint32)
    for i, w in enumerate(widths):
        if w > 0:
            mags[i] = rng.integers(0, 2 ** min(int(w), 32), k,
                                   dtype=np.uint64)
    full, fo, ft = bitpack.pack_blocks(jnp.asarray(mags), jnp.asarray(widths))
    tiled, to, tt = bitpack.pack_blocks_tiled(jnp.asarray(mags),
                                              jnp.asarray(widths),
                                              max_width=max_width)
    assert int(ft) == int(tt)
    assert np.array_equal(np.asarray(fo), np.asarray(to))
    assert tiled.shape[0] == b * ((k * max_width + 7) // 8)
    t = int(ft)
    assert np.array_equal(np.asarray(full)[:t], np.asarray(tiled)[:t])
    assert np.all(np.asarray(tiled)[t:] == 0)
    out = bitpack.unpack_blocks(tiled, jnp.asarray(widths), k)
    assert np.array_equal(np.asarray(out), mags)


def test_local_pack_kernel_matches_jnp():
    """kernels/bitpack_pack.py (interpret) == bitpack.local_pack_bytes."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for b, k, mw in [(17, 31, 8), (256, 31, 8), (100, 32, 4), (64, 16, 1),
                     (5, 8, 32)]:
        widths = rng.integers(0, mw + 1, b).astype(np.int32)
        mags = np.zeros((b, k), np.uint32)
        for i, w in enumerate(widths):
            if w > 0:
                mags[i] = rng.integers(0, 2 ** int(w), k, dtype=np.uint64)
        mj, wj = jnp.asarray(mags), jnp.asarray(widths)
        out_i = ops.local_pack(mj, wj, max_width=mw, backend="interpret")
        out_j = ops.local_pack(mj, wj, max_width=mw, backend="jnp")
        assert np.array_equal(np.asarray(out_i), np.asarray(out_j)), (b, k, mw)


def test_width_bucket():
    assert bitpack.width_bucket(0) == 1
    assert bitpack.width_bucket(1) == 1
    assert bitpack.width_bucket(3) == 4
    assert bitpack.width_bucket(6) == 8
    assert bitpack.width_bucket(9) == 16
    assert bitpack.width_bucket(17) == 32
    assert bitpack.width_bucket(32) == 32
    with pytest.raises(ValueError):
        bitpack.width_bucket(33)
    with pytest.raises(ValueError):
        bitpack.width_bucket(-1)


def test_sum_width_growth_law():
    """Partial sums over h members need ceil(log2(h)) extra bits."""
    assert bitpack.sum_width(6, 1) == 6
    assert bitpack.sum_width(6, 2) == 7
    assert bitpack.sum_width(6, 3) == 8
    assert bitpack.sum_width(6, 4) == 8
    assert bitpack.sum_width(6, 5) == 9
    assert bitpack.sum_width(30, 8) == 32    # capped at the packing limit
    assert bitpack.sum_width(33, 1) == 32


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 255), st.integers(1, 64))
def test_bits_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    packed = bitpack.pack_bits(jnp.asarray(bits))
    out = bitpack.unpack_bits(packed, n)
    assert np.array_equal(np.asarray(out), bits)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 255), st.integers(1, 64))
def test_2bit_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 4, n).astype(np.int32)
    packed = bitpack.pack_2bit(jnp.asarray(vals))
    out = bitpack.unpack_2bit(packed, n)
    assert np.array_equal(np.asarray(out), vals)
