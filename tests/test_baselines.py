"""Baseline compressors behave like their classes (paper Table II)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import (sz_lorenzo2d_compress,
                                  sz_lorenzo2d_decompress, topo_iter_compress,
                                  topo_iter_decompress, zfp_like_compress,
                                  zfp_like_decompress)
from repro.core.metrics import false_cases_host, max_abs_error


@pytest.mark.parametrize("eb", [1e-2, 1e-3])
def test_sz_lorenzo_bound_and_monotone_class(smooth_field, eb):
    f = jnp.asarray(smooth_field)
    c = sz_lorenzo2d_compress(f, eb)
    r = sz_lorenzo2d_decompress(c, f.shape, eb)
    assert float(max_abs_error(f, r)) <= eb * (1 + 1e-5)
    fc = false_cases_host(f, r)
    assert fc["FP"] == 0 and fc["FT"] == 0     # monotone per-value class


@pytest.mark.parametrize("eb", [1e-2, 1e-3])
def test_zfp_like_bound(smooth_field, eb):
    f = jnp.asarray(smooth_field)
    c = zfp_like_compress(f, eb)
    r = zfp_like_decompress(c, f.shape, eb)
    assert float(max_abs_error(f, r)) <= eb * (1 + 1e-4)


def test_zfp_like_produces_fp(vortex):
    """Transform coders are not monotone: they create false positives,
    which is exactly the paper's Table II observation for ZFP."""
    f = jnp.asarray(vortex)
    c = zfp_like_compress(f, 1e-2)
    r = zfp_like_decompress(c, f.shape, 1e-2)
    fc = false_cases_host(f, r)
    assert fc["FP"] > 0


def test_topo_iter_zero_false_cases(smooth_field):
    f = jnp.asarray(smooth_field)
    c = topo_iter_compress(f, 1e-2, max_iters=8)
    r = topo_iter_decompress(c, f.shape, 1e-2)
    fc = false_cases_host(f, r)
    assert fc["total"] == 0
    assert float(max_abs_error(f, r)) <= 1e-2 * (1 + 1e-5)
