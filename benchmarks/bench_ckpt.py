"""Checkpoint-subsystem benchmark (repro.ckpt v2).

Measures, on an optimizer-moment-shaped state tree (smooth f32 fields —
the data the paper's topology guarantees are about — plus small exact
leaves):

  (a) write + restore wall time per leaf mode (raw / szp / toposzp);
  (b) on-disk bytes per mode and the ratio vs raw — the compressed
      checkpoint win with the topology metadata overhead included;
  (c) the TopoSZp restore error (deterministic: must stay within the
      relaxed 2*eb bound, gated at exactly that);
  (d) the step-loop overlap win of the async writer: the per-``ckpt_every``
      stall the step loop observes with the synchronous writer (full
      serialize+fsync on the loop thread) vs the async writer (device->host
      snapshot only, serialize+fsync on a background thread) —
      ``stall_vs_sync`` is the machine-independent regression gate.

``--json PATH`` writes the versioned results file for
``benchmarks/check_regression.py`` (baseline: baseline_ckpt.json);
``--smoke`` shrinks the state for CI wall-clock.
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, reset_records, timeit, write_json
from repro import faults
from repro.ckpt import CheckpointManager
from repro.train import TrainState, train_loop

EB = 1e-3
MODES = ("raw", "szp", "toposzp")


def _state_tree(smoke: bool):
    """Optimizer-moment-like tree: smooth fields + noise (seeded)."""
    ny, nx = (256, 256) if smoke else (1024, 1024)
    rng = np.random.default_rng(0)
    y, x = np.meshgrid(np.linspace(0, 6 * np.pi, ny),
                       np.linspace(0, 6 * np.pi, nx), indexing="ij")
    base = np.sin(x) * np.cos(y)
    tree = {}
    for i, name in enumerate(("master", "m", "v")):
        f = (base * (1.0 + 0.1 * i)
             + 0.05 * rng.standard_normal((ny, nx))).astype(np.float32)
        tree[name] = jnp.asarray(np.abs(f) if name == "v" else f)
    tree["step"] = jnp.int32(123)
    tree["small"] = jnp.ones((16,), jnp.float32)
    return tree


def _dir_bytes(path: str) -> int:
    return sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(path) for f in fs)


def _bench_modes(tree, workdir: str):
    raw_disk = None
    for mode in MODES:
        d = os.path.join(workdir, mode)
        mgr = CheckpointManager(d, mode=mode, eb=EB, async_write=False,
                                log=None, keep=None)
        t_write = timeit(lambda m=mgr: m.save(tree, 1))
        path = os.path.join(d, "step_00000001")
        disk = _dir_bytes(path)
        if mode == "raw":
            raw_disk = disk
        t_restore = timeit(lambda m=mgr: m.restore(tree))
        res = mgr.restore(tree)
        max_err = max(float(jnp.abs(res.tree[k] - tree[k]).max())
                      for k in ("master", "m", "v"))
        emit(f"ckpt/write_{mode}", t_write * 1e6,
             {"disk_bytes": disk, "bytes_vs_raw": disk / raw_disk})
        emit(f"ckpt/restore_{mode}", t_restore * 1e6,
             {"max_abs_err": max_err, "eb": EB})


def _bench_async_overlap(tree, workdir: str, n_ckpts: int = 6,
                         steps_between: int = 5, step_ms: float = 10.0):
    """Per-checkpoint stall of the step loop, sync vs async writer.

    The fake step sleeps (GIL released) so the background writer overlaps
    exactly like a real device-bound step would."""
    def run(async_write: bool) -> float:
        d = os.path.join(workdir, f"overlap_{int(async_write)}")
        shutil.rmtree(d, ignore_errors=True)
        mgr = CheckpointManager(d, mode="raw", async_write=async_write,
                                log=None)
        stalls = []
        for step in range(1, n_ckpts + 1):
            for _ in range(steps_between):
                time.sleep(step_ms / 1e3)
            t0 = time.perf_counter()
            mgr.save(tree, step)
            stalls.append(time.perf_counter() - t0)
        mgr.wait()
        return float(np.median(stalls[1:]))   # drop the cold first write

    sync_stall = run(async_write=False)
    async_stall = run(async_write=True)
    emit("ckpt/async_overlap", async_stall * 1e6,
         {"sync_stall_us": sync_stall * 1e6,
          "async_stall_us": async_stall * 1e6,
          "stall_vs_sync": async_stall / sync_stall})


def _bench_coord_commit(tree, workdir: str, reps: int = 15):
    """Protocol overhead of the coordinated commit at world=1: the ready
    marker + barrier + fragment merge ride on top of the same blob write
    and publish, so coord/plain isolates exactly the protocol cost.
    ``commit_barrier_overhead`` is the machine-independent gate (<= 1.10x:
    the protocol must stay noise-level for single-process jobs, which all
    pay the code path when ``coordinated=True`` is forced).  Measured as
    the median of per-rep coord/plain ratios, where each rep's leg time
    is the MIN of 3 interleaved saves — the pairing shares each rep's
    filesystem-noise epoch (a ratio of aggregates flaps by +-20%), the
    within-rep order alternates (a fixed plain-then-coord order lets
    fsync drift land asymmetrically on the coord leg), and the min
    absorbs the heavy-tailed fsync latency spikes that a single save
    per leg passes straight into the ratio — on a fixed 3 MiB tree so
    the ~0.3 ms protocol cost is weighed against a save long enough to
    resolve it."""
    tree = {"w": jnp.asarray(np.random.default_rng(1)
                             .standard_normal((512, 512, 3))
                             .astype(np.float32))}

    def one(coordinated: bool) -> float:
        d = os.path.join(workdir, f"coord_{int(coordinated)}")
        shutil.rmtree(d, ignore_errors=True)
        mgr = CheckpointManager(d, mode="raw", async_write=False,
                                log=None, keep=None,
                                coordinated=coordinated,
                                process_index=0, process_count=1)
        t0 = time.perf_counter()
        mgr.save(tree, 1)
        return time.perf_counter() - t0

    one(False), one(True)                    # warm both paths
    pairs = []
    for r in range(reps):
        ps, cs = [], []
        for k in range(3):
            if (r + k) % 2 == 0:
                ps.append(one(False)), cs.append(one(True))
            else:
                cs.append(one(True)), ps.append(one(False))
        pairs.append((min(ps), min(cs)))
    plain = float(np.median([p for p, _ in pairs]))
    coordd = float(np.median([c for _, c in pairs]))
    overhead = float(np.median([c / p for p, c in pairs]))
    emit("ckpt/coord_commit", coordd * 1e6,
         {"plain_us": plain * 1e6, "coord_us": coordd * 1e6,
          "commit_barrier_overhead": overhead})


def _bench_recovery(workdir: str):
    """Wall time of one mid-run elastic recovery (device loss -> rolled
    back onto the last committed checkpoint, resharded, re-jitted):
    the ``recovery_time_s`` record of the fault-tolerance acceptance."""
    params = {"w": jnp.zeros((256, 256), jnp.float32)}

    def step_fn(state, batch):
        return (state._replace(step=state.step + 1,
                               params={"w": state.params["w"] + 1.0}),
                {"loss": jnp.float32(0.0)})

    def batches():
        while True:
            yield {"x": jnp.zeros(())}

    d = os.path.join(workdir, "recovery")
    mgr = CheckpointManager(d, mode="raw", async_write=True, log=None)
    plan = faults.FaultPlan(
        {"loop.step": faults.Fault("device_loss", at=3)})
    with faults.injected(plan):
        _, rep = train_loop(TrainState(jnp.int32(0), params, None, None),
                            step_fn, batches(), num_steps=4,
                            ckpt_manager=mgr, ckpt_every=2,
                            max_recoveries=1, log=lambda *_: None)
    assert len(rep.recoveries) == 1, "recovery bench did not recover"
    rec_s = rep.recoveries[0]["recovery_s"]
    emit("ckpt/recovery", rec_s * 1e6,
         {"recovery_time_s": rec_s,
          "restored_from": rep.recoveries[0]["restored_from"]})


def run(smoke: bool = False):
    tree = _state_tree(smoke)
    workdir = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        _bench_modes(tree, workdir)
        _bench_async_overlap(tree, workdir)
        _bench_coord_commit(tree, workdir)
        _bench_recovery(workdir)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None,
                    help="write machine-readable results to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI wall-clock")
    args = ap.parse_args()
    reset_records()
    run(smoke=args.smoke)
    if args.json:
        write_json(args.json, "bench_ckpt", smoke=args.smoke)


if __name__ == "__main__":
    main()
