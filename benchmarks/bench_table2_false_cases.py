"""Paper Table II: average FN/FP/FT per dataset x error bound x compressor.

Validates the paper's three claims at fixed error bounds:
  * TopoSZp: FP = 0 and FT = 0 everywhere,
  * TopoSZp: 3x-100x fewer FN than the non-topology-aware compressors,
  * ZFP-like transform coders produce nonzero FP (not monotone).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_grid, emit
from repro.core import false_cases_host, szp_compress, szp_decompress
from repro.core.baselines import (sz_lorenzo2d_compress,
                                  sz_lorenzo2d_decompress, zfp_like_compress,
                                  zfp_like_decompress)
from repro.core.toposzp import toposzp_compress, toposzp_decompress
from repro.data.fields import make_dataset

EBS = [1e-3, 1e-4, 1e-5]
DATASETS = ("ATM", "CLIMATE", "ICE", "LAND", "OCEAN")


def _roundtrip(name, f, eb):
    ny, nx = f.shape
    if name == "toposzp":
        c = toposzp_compress(f, eb)
        return toposzp_decompress(c, (ny, nx), eb)
    if name == "szp":
        return szp_decompress(szp_compress(f, eb), (ny, nx), eb)
    if name == "sz_lorenzo":
        return sz_lorenzo2d_decompress(sz_lorenzo2d_compress(f, eb),
                                       (ny, nx), eb)
    return zfp_like_decompress(zfp_like_compress(f, eb), (ny, nx), eb)


def run():
    for ds in DATASETS:
        ny, nx = bench_grid(ds)
        fields = [jnp.asarray(f[:ny, :nx])
                  for f in make_dataset(ds, n_fields=2, seed=5)]
        for eb in EBS:
            for comp in ("toposzp", "szp", "sz_lorenzo", "zfp_like"):
                tot = {"FN": 0, "FP": 0, "FT": 0}
                for f in fields:
                    fc = false_cases_host(f, _roundtrip(comp, f, eb))
                    for k in tot:
                        tot[k] += fc[k]
                avg = {k: v / len(fields) for k, v in tot.items()}
                emit(f"table2/{ds}/{comp}/eb{eb:.0e}", avg["FN"],
                     f"FN={avg['FN']:.1f};FP={avg['FP']:.1f};"
                     f"FT={avg['FT']:.1f}")


if __name__ == "__main__":
    run()
