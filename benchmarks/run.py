"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
Set REPRO_BENCH_FULL=1 for the paper's full grid sizes (slow on CPU).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_fig7_time, bench_fig8_rate_distortion,
                            bench_grad_compress, bench_table1_scalability,
                            bench_table2_false_cases)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_table1_scalability, bench_fig7_time,
                bench_fig8_rate_distortion, bench_table2_false_cases,
                bench_grad_compress):
        try:
            mod.run()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
