"""CI bench-regression gate for the grad-compression benchmark.

Compares a machine-readable results file (written by
``python -m benchmarks.bench_grad_compress --json ...``) against the
checked-in baseline and fails when a gated metric regresses beyond its
tolerance.  Gates live in the baseline file so the thresholds are
reviewed like code:

    {"schema_version": 1,
     "gates": [{"record": "gradcomp/step_compressed_psum",
                "metric": "wire_bits_per_val",
                "baseline": 9.0,
                "max_regression": 0.2,        # fail above 9.0 * 1.2
                "direction": "lower_is_better"}]}

``direction`` is ``lower_is_better`` (default; fails when current >
baseline * (1 + max_regression)) or ``higher_is_better`` (fails when
current < baseline * (1 - max_regression)).  Wall-clock gates use
machine-independent ratios (``time_vs_uncompressed``) rather than
absolute microseconds so laptop and CI runners share one baseline.

Usage:
    python benchmarks/check_regression.py results/bench_grad_compress.json \
        [--baseline benchmarks/baseline_grad_compress.json]
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_BASELINE = "benchmarks/baseline_grad_compress.json"
SCHEMA_VERSION = 1

# Legacy bench names still accepted from checked-in baselines: bench_serve
# wrote "serve" before the names were normalized to the module name.
BENCH_ALIASES = {"serve": "bench_serve"}


def canonical_bench(name):
    return BENCH_ALIASES.get(name, name)


def load_doc(path: str, what: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema_version") != SCHEMA_VERSION:
        raise SystemExit(f"unsupported {what} schema_version "
                         f"{doc.get('schema_version')!r} in {path}")
    return doc


def load_metrics(results_path: str) -> dict:
    """Flatten a results file into {record_name: {metric: value}}."""
    doc = load_doc(results_path, "results")
    out = {}
    for rec in doc.get("records", []):
        metrics = dict(rec.get("metrics", {}))
        metrics["us_per_call"] = rec.get("us_per_call")
        out[rec["name"]] = metrics
    return out


def check_gate(gate: dict, current: dict) -> str | None:
    """Return a failure message for one gate, or None when it passes."""
    record, metric = gate["record"], gate["metric"]
    base = float(gate["baseline"])
    tol = float(gate.get("max_regression", 0.2))
    direction = gate.get("direction", "lower_is_better")
    rec = current.get(record)
    if rec is None:
        return f"{record}: record missing from results"
    if metric not in rec:
        return f"{record}.{metric}: metric missing from results"
    value = float(rec[metric])
    if direction == "higher_is_better":
        limit = base * (1 - tol)
        if value < limit:
            return (f"{record}.{metric}: {value:.4g} < {limit:.4g} "
                    f"(baseline {base:.4g}, -{tol:.0%} tolerance)")
    else:
        limit = base * (1 + tol)
        if value > limit:
            return (f"{record}.{metric}: {value:.4g} > {limit:.4g} "
                    f"(baseline {base:.4g}, +{tol:.0%} tolerance)")
    return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", help="results JSON written by the benchmark")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    args = ap.parse_args()

    baseline = load_doc(args.baseline, "baseline")
    gates = baseline.get("gates", [])
    if not gates:
        raise SystemExit(f"no gates defined in {args.baseline}")

    results_doc = load_doc(args.results, "results")
    rb, bb = results_doc.get("bench"), baseline.get("bench")
    if (rb is not None and bb is not None
            and canonical_bench(rb) != canonical_bench(bb)):
        raise SystemExit(f"bench mismatch: results are from "
                         f"{rb!r} but the baseline gates {bb!r}")

    current = load_metrics(args.results)
    failures = []
    for gate in gates:
        msg = check_gate(gate, current)
        tag = "FAIL" if msg else "ok  "
        shown = msg or (f"{gate['record']}.{gate['metric']} = "
                        f"{current[gate['record']][gate['metric']]:.4g} "
                        f"(baseline {float(gate['baseline']):.4g})")
        print(f"[gate] {tag} {shown}")
        if msg:
            failures.append(msg)

    if failures:
        print(f"[gate] {len(failures)}/{len(gates)} gates regressed")
        return 1
    print(f"[gate] all {len(gates)} gates green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
