"""Beyond-paper: gradient-compression wire-bytes + fidelity benchmark.

Measures (a) the bits/value the quantized gradient codes need at several
relative error bounds (the DP all-reduce byte reduction vs bf16/f32 wire),
and (b) the homomorphic-sum error across simulated DP members — the
collective-term reduction claimed in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.dist.collectives import code_bits, quantize_dequantize_sum


def run():
    rng = np.random.default_rng(0)
    # gradient-shaped data: heavy-tailed, small magnitude
    g = (rng.standard_normal((16, 1 << 20)) * 1e-3).astype(np.float32)
    g[:, :100] *= 100.0                       # outliers like real grads
    gj = jnp.asarray(g)

    for rel_eb in (1e-2, 1e-3, 1e-4):
        bits = int(code_bits(gj[0], rel_eb))
        homo, direct = quantize_dequantize_sum(gj, rel_eb=rel_eb)
        err = float(jnp.abs(homo - direct).max())
        scale = float(jnp.abs(gj).max())
        t = timeit(lambda: quantize_dequantize_sum(gj, rel_eb=rel_eb))
        emit(f"gradcomp/rel_eb{rel_eb:.0e}", t * 1e6,
             f"bits_per_val={bits};wire_reduction_vs_bf16={16 / bits:.1f}x;"
             f"homo_err={err:.3e};rel={err / scale:.2e}")


if __name__ == "__main__":
    run()
