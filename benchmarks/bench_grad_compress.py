"""Beyond-paper: gradient-compression wire-bytes + fidelity benchmark.

Measures (a) the bits/value the quantized gradient codes need at several
relative error bounds (the DP all-reduce byte reduction vs bf16/f32 wire),
(b) the homomorphic-sum error across simulated DP members — the
collective-term reduction claimed in EXPERIMENTS.md §Perf — and (c) the
end-to-end train-step time of the compressed-psum shard_map path vs the
baseline (uncompressed bf16 all-reduce inserted by GSPMD).

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise a
real multi-member data-parallel reduction; on a single device the psum is
a 1-member identity but the full compression path still runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.dist.collectives import code_bits, quantize_dequantize_sum


def run():
    rng = np.random.default_rng(0)
    # gradient-shaped data: heavy-tailed, small magnitude
    g = (rng.standard_normal((16, 1 << 20)) * 1e-3).astype(np.float32)
    g[:, :100] *= 100.0                       # outliers like real grads
    gj = jnp.asarray(g)

    for rel_eb in (1e-2, 1e-3, 1e-4):
        bits = int(code_bits(gj[0], rel_eb))
        homo, direct = quantize_dequantize_sum(gj, rel_eb=rel_eb)
        err = float(jnp.abs(homo - direct).max())
        scale = float(jnp.abs(gj).max())
        t = timeit(lambda: quantize_dequantize_sum(gj, rel_eb=rel_eb))
        emit(f"gradcomp/rel_eb{rel_eb:.0e}", t * 1e6,
             f"bits_per_val={bits};wire_reduction_vs_bf16={16 / bits:.1f}x;"
             f"homo_err={err:.3e};rel={err / scale:.2e}")

    _bench_train_step(rel_eb=1e-3)


def _bench_train_step(rel_eb: float):
    """Compressed-psum train step vs the uncompressed-psum baseline."""
    from repro.dist import sharding as shd
    from repro.dist.elastic import rebuild_mesh
    from repro.data import token_batches
    from repro.models import lm, registry
    from repro.optim import adamw, constant
    from repro.train import init_state, make_train_step

    cfg = registry.get_smoke_config("gemma2_2b")
    mesh = rebuild_mesh(jax.devices(), model_parallel=1)
    n_dp = mesh.shape["data"]
    b = n_dp * max(1, 8 // n_dp)
    batch = jax.tree.map(jnp.asarray, next(token_batches(cfg, b, 32, seed=0)))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant(1e-3))

    # baseline: data-sharded batch, GSPMD inserts the bf16 DP all-reduce
    batch_sh = shd.data_sharding(batch, mesh, "tp")
    state_b = init_state(params, opt, grad_compress=False)
    step_b = jax.jit(make_train_step(cfg, opt), in_shardings=(None, batch_sh))
    t_b = timeit(lambda: step_b(state_b, batch)[1]["loss"])

    # compressed: quantized codes on the DP wire + error feedback
    state_c = init_state(params, opt, grad_compress=True)
    step_c = jax.jit(make_train_step(cfg, opt, mesh=mesh, grad_compress=True,
                                     rel_eb=rel_eb))
    loss_c = float(step_c(state_c, batch)[1]["loss"])
    assert np.isfinite(loss_c), "compressed step produced non-finite loss"
    t_c = timeit(lambda: step_c(state_c, batch)[1]["loss"])

    # wire width of the REAL step gradients (size-weighted mean bits/value)
    grads = jax.jit(jax.grad(lambda p: lm.loss_fn(p, cfg, batch)))(params)
    leaves = [g.astype(jnp.float32) for g in jax.tree.leaves(grads)]
    total = sum(g.size for g in leaves)
    bits = sum(g.size * int(code_bits(g, rel_eb)) for g in leaves) / total
    emit("gradcomp/step_uncompressed_psum", t_b * 1e6,
         f"dp_members={n_dp};loss_finite=1")
    emit("gradcomp/step_compressed_psum", t_c * 1e6,
         f"dp_members={n_dp};time_vs_uncompressed={t_c / t_b:.2f}x;"
         f"wire_bits_per_val={bits:.1f};"
         f"wire_reduction_vs_bf16={16 / bits:.1f}x;loss={loss_c:.4f}")


if __name__ == "__main__":
    run()
