"""Beyond-paper: gradient-compression wire-bytes + fidelity benchmark.

Measures (a) the bits/value the quantized gradient codes need at several
relative error bounds (the DP all-reduce byte reduction vs bf16/f32 wire),
(b) the homomorphic-sum error across simulated DP members — the
collective-term reduction claimed in EXPERIMENTS.md §Perf — (c) the
topology-aware collective: protected-tail size, sidecar wire overhead and
top-k rank-preservation rate vs the plain compressed psum, (d) the MEASURED
packed-wire bytes of the dist.ring bitpacked ppermute all-reduce: the
per-hop bytes each member actually packs (valid) and ships (static cap)
vs the int32 ring reference — the ``packed_vs_int32`` regression gate —
and (e) the end-to-end train-step time of the compressed /
topo-compressed / packed-ring shard_map paths vs the baseline
(uncompressed bf16 all-reduce inserted by GSPMD).

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise a
real multi-member data-parallel reduction; on a single device the psum is
a 1-member identity but the full compression path still runs.

``--json PATH`` writes the machine-readable results file the CI
regression gate (benchmarks/check_regression.py) consumes; ``--smoke``
shrinks the arrays for CI wall-clock.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, reset_records, timeit, write_json
from repro.dist.collectives import (code_bits, protect_k,
                                    quantize_dequantize_sum, sidecar_bits,
                                    topk_rank_preservation,
                                    topo_quantize_dequantize_sum,
                                    topo_wire_bits)
from repro.dist.ring import packed_wire_summary, simulate_hop_bytes

TOPO_FRAC = 1e-3          # protected-tail knob exercised by the benchmark
RANK_TOP_K = 64           # tail size the rank-preservation rate reports


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    # gradient-shaped data: heavy-tailed, small magnitude
    n_members, size = (8, 1 << 17) if smoke else (16, 1 << 20)
    g = (rng.standard_normal((n_members, size)) * 1e-3).astype(np.float32)
    g[:, :100] *= 100.0                       # outliers like real grads
    gj = jnp.asarray(g)
    rel_ebs = (1e-2, 1e-3) if smoke else (1e-2, 1e-3, 1e-4)

    for rel_eb in rel_ebs:
        bits = int(code_bits(gj[0], rel_eb))
        homo, direct = quantize_dequantize_sum(gj, rel_eb=rel_eb)
        err = float(jnp.abs(homo - direct).max())
        scale = float(jnp.abs(gj).max())
        t = timeit(lambda: quantize_dequantize_sum(gj, rel_eb=rel_eb))
        emit(f"gradcomp/rel_eb{rel_eb:.0e}", t * 1e6, {
            "bits_per_val": bits,
            "wire_reduction_vs_bf16": 16 / bits,
            "homo_err": err,
            "rel": err / scale,
        })
        _bench_topo(gj, rel_eb, homo, direct)
        _bench_packed_wire(gj, rel_eb)

    _bench_train_step(rel_eb=1e-3, smoke=smoke)


def _bench_topo(gj: jnp.ndarray, rel_eb: float, plain_homo: jnp.ndarray,
                direct: jnp.ndarray):
    """Topo-aware homomorphic sum: tail size, wire overhead, rank rate."""
    n_members, size = gj.shape
    k = protect_k(size, TOPO_FRAC)
    topo, _, protected = topo_quantize_dequantize_sum(gj, rel_eb, TOPO_FRAC)
    exact = float(jnp.max(jnp.abs(topo[protected] - direct[protected])))
    body_bits = int(code_bits(gj[0], rel_eb)) * size
    side_bits = sidecar_bits(size, TOPO_FRAC, n_members)
    overhead = side_bits / (body_bits + side_bits)
    t = timeit(lambda: topo_quantize_dequantize_sum(gj, rel_eb, TOPO_FRAC))
    emit(f"gradcomp/topo_rel_eb{rel_eb:.0e}", t * 1e6, {
        "topo_frac": TOPO_FRAC,
        "protected_per_member": k,
        "protected_union": int(np.unique(np.asarray(protected)).size),
        "protected_max_err": exact,
        "sidecar_bits_per_val": side_bits / size,
        "sidecar_overhead_frac": overhead,
        f"rank_preservation_top{RANK_TOP_K}":
            topk_rank_preservation(direct, topo, RANK_TOP_K),
        f"rank_preservation_top{RANK_TOP_K}_plain":
            topk_rank_preservation(direct, plain_homo, RANK_TOP_K),
    })


def _bench_packed_wire(gj: jnp.ndarray, rel_eb: float):
    """Measured bytes of the bitpacked ring wire on the member codes.

    Replays the ring's per-hop partial-sum schedule and packs every
    member's payload for real (``dist.ring.simulate_hop_bytes``): the
    ``valid`` bytes are what the packed stream holds, the ``shipped``
    bytes the statically-capped ppermute buffer, both vs the int32 ring
    reference (4 bytes/value/hop).
    """
    from repro.core.quantize import quantize
    eb = jnp.maximum(jnp.abs(gj).max() * rel_eb, 1e-30)
    qs = quantize(gj, eb)
    rec = simulate_hop_bytes(qs, rel_eb)
    t = timeit(lambda: simulate_hop_bytes(quantize(gj, eb), rel_eb))
    emit(f"gradcomp/packed_rel_eb{rel_eb:.0e}", t * 1e6, {
        "hops": rec["hops"],
        "valid_bytes_per_hop": rec["valid_bytes_per_hop"],
        "shipped_bytes_per_hop": rec["shipped_bytes_per_hop"],
        "int32_bytes_per_hop": rec["int32_bytes_per_hop"],
        "valid_vs_int32": rec["valid_vs_int32"],
        "shipped_vs_int32": rec["shipped_vs_int32"],
    })


def _bench_train_step(rel_eb: float, smoke: bool = False):
    """Compressed / topo-compressed train step vs the uncompressed psum."""
    from repro.data import token_batches
    from repro.dist import sharding as shd
    from repro.dist.elastic import rebuild_mesh
    from repro.models import lm, registry
    from repro.optim import adamw, constant
    from repro.train import init_state, make_train_step

    cfg = registry.get_smoke_config("gemma2_2b")
    mesh = rebuild_mesh(jax.devices(), model_parallel=1)
    n_dp = mesh.shape["data"]
    b = n_dp * max(1, 8 // n_dp)
    seq = 16 if smoke else 32
    batch = jax.tree.map(jnp.asarray,
                         next(token_batches(cfg, b, seq, seed=0)))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant(1e-3))

    # baseline: data-sharded batch, GSPMD inserts the bf16 DP all-reduce
    batch_sh = shd.data_sharding(batch, mesh, "tp")
    state_b = init_state(params, opt, grad_compress=False)
    step_b = jax.jit(make_train_step(cfg, opt), in_shardings=(None, batch_sh))
    t_b = timeit(lambda: step_b(state_b, batch)[1]["loss"])

    # compressed: quantized codes on the DP wire + error feedback
    state_c = init_state(params, opt, grad_compress=True)
    step_c = jax.jit(make_train_step(cfg, opt, mesh=mesh, grad_compress=True,
                                     rel_eb=rel_eb))
    loss_c = float(step_c(state_c, batch)[1]["loss"])
    assert np.isfinite(loss_c), "compressed step produced non-finite loss"
    t_c = timeit(lambda: step_c(state_c, batch)[1]["loss"])

    # topo-compressed: exact top-|g| sidecar riding the quantized stream
    state_t = init_state(params, opt, grad_compress=True)
    step_t = jax.jit(make_train_step(cfg, opt, mesh=mesh, grad_compress=True,
                                     rel_eb=rel_eb, topo_frac=TOPO_FRAC))
    loss_t = float(step_t(state_t, batch)[1]["loss"])
    assert np.isfinite(loss_t), "topo step produced non-finite loss"
    t_t = timeit(lambda: step_t(state_t, batch)[1]["loss"])

    # packed ring: the bitpacked ppermute wire end-to-end
    state_p = init_state(params, opt, grad_compress=True)
    step_p = jax.jit(make_train_step(cfg, opt, mesh=mesh, grad_compress=True,
                                     rel_eb=rel_eb, topo_frac=TOPO_FRAC,
                                     wire_format="packed"))
    loss_p = float(step_p(state_p, batch)[1]["loss"])
    assert np.isfinite(loss_p), "packed step produced non-finite loss"
    t_p = timeit(lambda: step_p(state_p, batch)[1]["loss"])

    # wire width of the REAL step gradients (size-weighted mean bits/value)
    grads = jax.jit(jax.grad(lambda p: lm.loss_fn(p, cfg, batch)))(params)
    leaves = [g.astype(jnp.float32) for g in jax.tree.leaves(grads)]
    total = sum(g.size for g in leaves)
    body = sum(g.size * int(code_bits(g, rel_eb)) for g in leaves)
    topo_total = sum(topo_wire_bits(g, rel_eb, TOPO_FRAC, n_dp)
                     for g in leaves)
    side = topo_total - body
    protected = sum(protect_k(g.size, TOPO_FRAC) for g in leaves)

    emit("gradcomp/step_uncompressed_psum", t_b * 1e6,
         {"dp_members": n_dp, "loss_finite": 1})
    emit("gradcomp/step_compressed_psum", t_c * 1e6, {
        "dp_members": n_dp,
        "time_vs_uncompressed": t_c / t_b,
        "wire_bits_per_val": body / total,
        "wire_reduction_vs_bf16": 16 * total / body,
        "loss": loss_c,
    })
    emit("gradcomp/step_topo_compressed_psum", t_t * 1e6, {
        "dp_members": n_dp,
        "topo_frac": TOPO_FRAC,
        "time_vs_uncompressed": t_t / t_b,
        "time_vs_compressed": t_t / t_c,
        "protected_per_member": protected,
        "wire_bits_per_val": (body + side) / total,
        "sidecar_bits_per_val": side / total,
        "sidecar_overhead_frac": side / (body + side),
        "wire_reduction_vs_bf16": 16 * total / (body + side),
        "loss": loss_t,
    })
    ring_model = packed_wire_summary([g.size for g in leaves], rel_eb,
                                     TOPO_FRAC, n_dp)
    emit("gradcomp/step_packed_ring", t_p * 1e6, {
        "dp_members": n_dp,
        "topo_frac": TOPO_FRAC,
        "time_vs_uncompressed": t_p / t_b,
        "time_vs_compressed": t_p / t_c,
        "ring_hops": ring_model["hops"],
        "packed_bytes_per_hop": ring_model["packed_bytes_per_hop"],
        "packed_vs_int32_per_hop": ring_model["packed_vs_int32_per_hop"],
        "loss": loss_p,
    })


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None,
                    help="write machine-readable results to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI wall-clock")
    args = ap.parse_args()
    reset_records()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
    if args.json:
        write_json(args.json, bench="bench_grad_compress", smoke=args.smoke)


if __name__ == "__main__":
    main()
