"""Paper Fig. 8: bit rate vs false cases (FN / FP / FT / total).

Sweeps the error bound to trace the rate-distortion curve for TopoSZp,
SZp, SZ-Lorenzo2D and ZFP-like on every dataset.  Emits one row per
(dataset, compressor, eb): derived = bitrate + false-case counts.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_grid, emit
from repro.core import false_cases_host, szp_compress, szp_decompress
from repro.core.baselines import (sz_lorenzo2d_compress,
                                  sz_lorenzo2d_decompress, zfp_like_compress,
                                  zfp_like_decompress)
from repro.core.toposzp import toposzp_compress, toposzp_decompress
from repro.data.fields import multiscale_field

EBS = [1e-2, 1e-3, 1e-4]


def run():
    for ds in ("CLIMATE", "ICE", "LAND"):
        ny, nx = bench_grid(ds)
        f = jnp.asarray(multiscale_field(ny, nx, seed=21))
        n = f.size
        for eb in EBS:
            rows = {}
            comp = toposzp_compress(f, eb)
            rec = toposzp_decompress(comp, (ny, nx), eb)
            rows["toposzp"] = (int(comp.nbytes), rec)

            parts = szp_compress(f, eb)
            rows["szp"] = (int(parts.nbytes),
                           szp_decompress(parts, (ny, nx), eb))

            c = sz_lorenzo2d_compress(f, eb)
            rows["sz_lorenzo"] = (int(c.nbytes),
                                  sz_lorenzo2d_decompress(c, (ny, nx), eb))

            z = zfp_like_compress(f, eb)
            rows["zfp_like"] = (int(z.nbytes),
                                zfp_like_decompress(z, (ny, nx), eb))

            for name, (nbytes, r) in rows.items():
                fc = false_cases_host(f, r)
                bitrate = 8.0 * nbytes / n
                emit(f"fig8/{ds}/{name}/eb{eb:.0e}", bitrate * 1000,
                     f"bitrate={bitrate:.3f};FN={fc['FN']};FP={fc['FP']};"
                     f"FT={fc['FT']};total={fc['total']}")


if __name__ == "__main__":
    run()
