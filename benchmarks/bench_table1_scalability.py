"""Paper Table I: compression-time scalability + the eps_topo <= 2 eps bound.

The paper scales OpenMP threads 1->18 on fixed grids; the TPU-native analog
is data-parallel sharding, which on this 1-core CPU container we surface as
throughput over the same datasets plus the measured eps_topo.  Emits one CSV
row per dataset: name, us_per_call(compress), derived = "MB/s=..,ratio=..,
eps_topo=..".
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_grid, emit, timeit
from repro.core import max_abs_error
from repro.core.toposzp import toposzp_compress, toposzp_decompress
from repro.data.fields import gaussian_random_field

EB = 1e-3


def run():
    for name in ("ATM", "CLIMATE", "ICE", "LAND", "OCEAN"):
        ny, nx = bench_grid(name)
        f = jnp.asarray(gaussian_random_field(ny, nx, seed=7))
        comp = toposzp_compress(f, EB)             # compile
        t_c = timeit(lambda: toposzp_compress(f, EB))
        rec = toposzp_decompress(comp, (ny, nx), EB)
        t_d = timeit(lambda: toposzp_decompress(comp, (ny, nx), EB))
        mb = f.size * 4 / 1e6
        eps_topo = float(max_abs_error(f, rec))
        ratio = f.size * 4 / int(comp.nbytes)
        emit(f"table1/{name}/compress", t_c * 1e6,
             f"MB/s={mb / t_c:.1f};ratio={ratio:.2f};"
             f"eps_topo={eps_topo:.2e};bound2eb={2 * EB:.0e}")
        emit(f"table1/{name}/decompress", t_d * 1e6,
             f"MB/s={mb / t_d:.1f}")


if __name__ == "__main__":
    run()
