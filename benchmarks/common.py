"""Shared benchmark helpers: timing, CSV/JSON emission, dataset sizing."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Union

import jax
import numpy as np

# benchmark-scale knob: FULL=1 uses the paper's grid sizes (ATM 1800x3600);
# default runs reduced grids so the suite finishes quickly on 1 CPU core.
FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

REDUCED = {
    "ATM": (450, 900),
    "CLIMATE": (384, 576),
    "ICE": (384, 320),
    "LAND": (192, 288),
    "OCEAN": (384, 320),
}


def bench_grid(name: str):
    from repro.data.fields import DATASETS
    return DATASETS[name] if FULL else REDUCED[name]


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time of a blocking call (jit warm)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# JSON results schema (benchmarks/check_regression.py consumes this):
#   {"schema_version": 1, "records": [
#       {"name": str, "us_per_call": float, "metrics": {str: float|int|str}}]}
SCHEMA_VERSION = 1

_RECORDS: List[Dict] = []

Metrics = Union[str, Dict[str, object]]


def reset_records() -> None:
    _RECORDS.clear()


def records() -> List[Dict]:
    return list(_RECORDS)


def emit(name: str, us_per_call: float, derived: Metrics = ""):
    """Record one benchmark row and print the legacy CSV line.

    ``derived`` may be a pre-formatted ``k=v;...`` string (legacy) or a
    dict of metrics; dicts are what the JSON results file and the
    regression gate consume.
    """
    if isinstance(derived, dict):
        metrics = derived
        text = ";".join(f"{k}={_fmt(v)}" for k, v in derived.items())
    else:
        metrics = {"derived": derived} if derived else {}
        text = derived
    _RECORDS.append({"name": name, "us_per_call": float(us_per_call),
                     "metrics": metrics})
    print(f"{name},{us_per_call:.1f},{text}")


def _fmt(v) -> str:
    return f"{v:.4g}" if isinstance(v, float) else str(v)


def write_json(path: str, bench: str, smoke: Optional[bool] = None) -> None:
    """Write the collected records as a machine-readable results file."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    doc = {"schema_version": SCHEMA_VERSION, "bench": bench,
           "records": records()}
    if smoke is not None:
        doc["smoke"] = smoke
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench] wrote {path}")
