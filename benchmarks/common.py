"""Shared benchmark helpers: timing, CSV emission, dataset sizing."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# benchmark-scale knob: FULL=1 uses the paper's grid sizes (ATM 1800x3600);
# default runs reduced grids so the suite finishes quickly on 1 CPU core.
FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

REDUCED = {
    "ATM": (450, 900),
    "CLIMATE": (384, 576),
    "ICE": (384, 320),
    "LAND": (192, 288),
    "OCEAN": (384, 320),
}


def bench_grid(name: str):
    from repro.data.fields import DATASETS
    return DATASETS[name] if FULL else REDUCED[name]


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time of a blocking call (jit warm)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
