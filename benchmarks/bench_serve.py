"""Continuous-batching serve benchmark (repro.serve).

Runs a mixed-length request trace through three serving modes on one
smoke-scale arch and reports, per mode:

  (a) decode throughput (tokens/s) and p50/p99 step latency;
  (b) the continuous-vs-greedy throughput ratio — the batching win the
      continuous engine must keep (greedy = the pre-paging per-request
      B=1 ``ServeEngine`` loop);
  (c) resident paged-KV bytes vs the raw-cache equivalent at peak
      occupancy (``kv_resident_ratio``) — the tiered-compression win;
  (d) the TopoSZp page guarantees, hard-gated: every compressed page
      field stays within 2*eb of the original and introduces zero false
      critical points (``err_over_bound`` <= 1, ``false_critical_points``
      == 0), and the ``kv_mode="raw"`` trace stays token-identical to
      greedy (``mismatch_tokens`` == 0).

The serve caches run in float32 (the CPU compute dtype — bf16 on CI
runners is emulated); the trace is biased toward repeated-token prompts,
whose KV trajectories are temporally smooth like the paper's scientific
fields (random-token prompts are the adversarial case and two ride along
in the trace).

``--json PATH`` writes the versioned results file for
``benchmarks/check_regression.py`` (baseline: baseline_serve.json);
``--smoke`` shrinks the trace for CI wall-clock.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, reset_records, write_json
from repro import obs
from repro.models import lm, registry
from repro.serve import ContinuousServeEngine, Request, ServeEngine

EB = 0.16
PAGE_SIZE = 8
MAX_LEN = 128

# (prompt_len, max_new_tokens, prompt kind); "rep" = repeated token
# (temporally smooth KV), "rand" = iid random tokens (adversarial).
TRACE = [(16, 48, "rep"), (32, 64, "rep"), (8, 56, "rand"), (48, 72, "rep"),
         (16, 64, "rep"), (32, 48, "rep"), (8, 40, "rand"), (16, 56, "rep")]


def make_trace(cfg, smoke: bool):
    specs = TRACE if smoke else TRACE * 3
    reqs = []
    for i, (plen, new, kind) in enumerate(specs):
        if kind == "rep":
            toks = jnp.full((1, plen), (7 * i + 3) % cfg.vocab_size,
                            jnp.int32)
        else:
            toks = jax.random.randint(jax.random.PRNGKey(100 + i),
                                      (1, plen), 0, cfg.vocab_size)
        reqs.append(Request(rid=i, inputs={"tokens": toks},
                            max_new_tokens=new))
    return reqs


def run_greedy(cfg, params, reqs):
    eng = ServeEngine(cfg, params, max_len=MAX_LEN)
    for r in reqs:                                     # compile
        eng.generate(r.inputs, r.max_new_tokens)
    t0 = time.perf_counter()
    toks = {r.rid: np.asarray(eng.generate(r.inputs, r.max_new_tokens))[0]
            for r in reqs}
    dt = time.perf_counter() - t0
    n = sum(len(t) for t in toks.values())
    return toks, n / dt, dt


def run_continuous(cfg, params, reqs, kv_mode: str, num_slots: int):
    eng = ContinuousServeEngine(cfg, params, max_len=MAX_LEN,
                                num_slots=num_slots, page_size=PAGE_SIZE,
                                kv_mode=kv_mode, kv_eb=EB,
                                verify_guarantees=(kv_mode != "raw"))
    eng.serve(reqs)                                    # compile
    t0 = time.perf_counter()
    rep = eng.serve(reqs)
    dt = time.perf_counter() - t0
    return rep, rep.generated_tokens / dt, dt


def kv_peak_ratio(rep):
    """resident/raw bytes at the step with peak raw-equivalent occupancy
    (the capacity one would otherwise provision)."""
    peak = max(rep.kv_samples, key=lambda s: s["raw_equiv_bytes"],
               default=None)
    if not peak or not peak["raw_equiv_bytes"]:
        return 1.0, 0.0
    return (peak["resident_bytes"] / peak["raw_equiv_bytes"],
            peak["cold_pages"] / peak["occupied_pages"])


def obs_overhead_record(cfg, params, reqs, num_slots: int) -> None:
    """Obs-enabled vs obs-disabled serve wall time on the compressing
    tier (interleaved min-of-3 pairs; the CI gate in baseline_serve.json
    holds ``obs_vs_off`` at <= 1.05x).  The obs-on runs also exercise the
    per-sweep counter feed at the existing ``_finalize_sweep`` sync."""
    eng = ContinuousServeEngine(cfg, params, max_len=MAX_LEN,
                                num_slots=num_slots, page_size=PAGE_SIZE,
                                kv_mode="szp", kv_eb=EB)
    was = obs.enabled()
    obs.set_enabled(False)
    eng.serve(reqs)                                    # compile
    obs.set_enabled(True)
    eng.serve(reqs)
    t_off = t_on = None
    for _ in range(3):
        obs.set_enabled(False)
        t0 = time.perf_counter()
        eng.serve(reqs)
        toff = time.perf_counter() - t0
        obs.set_enabled(True)
        t0 = time.perf_counter()
        rep = eng.serve(reqs)
        ton = time.perf_counter() - t0
        t_off = toff if t_off is None else min(t_off, toff)
        t_on = ton if t_on is None else min(t_on, ton)
    obs.set_enabled(was)
    obs.reset()
    emit("serve/obs_overhead", 1e6 * t_on / rep.generated_tokens, {
        "obs_vs_off": t_on / t_off,
    })


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--num-slots", type=int, default=4)
    args = ap.parse_args()

    reset_records()
    cfg = registry.get_smoke_config(args.arch).replace(
        activation_dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = make_trace(cfg, args.smoke)

    greedy_toks, greedy_tps, greedy_dt = run_greedy(cfg, params, reqs)
    n_tok = sum(len(t) for t in greedy_toks.values())
    emit("serve/greedy_b1", 1e6 * greedy_dt / n_tok,
         {"tokens_per_s": greedy_tps, "tokens": n_tok})

    for kv_mode in ("raw", "szp", "toposzp"):
        rep, tps, dt = run_continuous(cfg, params, reqs, kv_mode,
                                      args.num_slots)
        ratio, cold_frac = kv_peak_ratio(rep)
        st = rep.pool_stats
        metrics = {
            "tokens_per_s": tps,
            "tokens": rep.generated_tokens,
            "steps": rep.steps,
            "p50_step_ms": 1e3 * float(np.percentile(rep.step_times, 50)),
            "p99_step_ms": 1e3 * float(np.percentile(rep.step_times, 99)),
            "speedup_vs_greedy": tps / greedy_tps,
            "kv_resident_ratio": ratio,
            "cold_page_fraction": cold_frac,
            "pages_compressed": st["pages_compressed"],
        }
        if kv_mode == "raw":
            metrics["mismatch_tokens"] = sum(
                int(np.sum(rep.tokens[r.rid] != greedy_toks[r.rid]))
                for r in reqs)
        else:
            metrics["err_over_bound"] = st["max_abs_err"] / (2 * EB)
            metrics["false_critical_points"] = st["false_critical_points"]
            metrics["fields_verified"] = st["fields_verified"]
        emit(f"serve/continuous_{kv_mode}", 1e6 * dt / rep.generated_tokens,
             metrics)

    obs_overhead_record(cfg, params, reqs, args.num_slots)

    if args.json:
        write_json(args.json, "bench_serve", smoke=args.smoke)


if __name__ == "__main__":
    main()
