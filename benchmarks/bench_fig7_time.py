"""Paper Fig. 7: topology-aware compressor runtime comparison.

TopoSZp vs the TopoIter baseline (the TopoSZ/TopoA stand-in: iterative
global correction with persistence-style passes).  The paper reports
100x-10000x compression and 10x-500x decompression speedups for TopoSZp;
the derived column carries the measured speedup factors.

Beyond the paper figure, this is the CORE-COMPRESSOR regression bench
(benchmarks/baseline_core.json gates it in CI like gradcomp/ckpt):

  * per-stage timings of the production pipeline (detect = CD, quant =
    fused QZ+LZ + rank metadata, pack = tiled BE, restore = CP^+RP^+RS^),
  * the BE-stage peak buffer (tiled static bucket vs the legacy 32-bit
    worst case — the >= 4x capacity contract at eb=1e-3) and the
    tiled-vs-worstcase pack time,
  * the batched multi-field API vs a per-field loop.

``--json PATH`` writes the machine-readable results file the CI
regression gate (benchmarks/check_regression.py) consumes; ``--smoke``
shrinks the field count / TopoIter passes for CI wall-clock.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import bench_grid, emit, reset_records, timeit, \
    write_json
from repro import obs
from repro.core import bitpack
from repro.core.baselines import (topo_iter_compress, topo_iter_decompress)
from repro.core.szp import (DEFAULT_BLOCK, szp_compress, szp_decompress)
from repro.core.toposzp import (_measure_one, _pack_streams,
                                toposzp_compress, toposzp_compress_batch,
                                toposzp_decompress,
                                toposzp_decompress_batch)
from repro.data.fields import gaussian_random_field, vortex_field
from repro.kernels import ops

EB = 1e-3
FIELDS = ["AEROD", "CLDHGH", "CLDLOW", "FLDSC", "CLDMED"]   # ATM fields


def _stage_records(f: jnp.ndarray, backend: str) -> None:
    """Per-stage timings + BE buffer accounting on one CLIMATE field."""
    ny, nx = f.shape
    block = DEFAULT_BLOCK
    detect = timeit(lambda: ops.cp_detect(f, backend=backend))
    quant = timeit(lambda: _measure_one(f, EB, block=block, backend=backend))
    measured = _measure_one(f, EB, block=block, backend=backend)
    main, rank, labels2b, n_cp, w_max, rw_max = measured
    mw_main = bitpack.width_bucket(int(w_max))
    mw_rank = bitpack.width_bucket(int(rw_max))
    pack = timeit(lambda: _pack_streams(main, rank, labels2b, n_cp,
                                        block=block, mw_main=mw_main,
                                        mw_rank=mw_rank, backend=backend))
    # legacy one-shot pack at the 32-bit worst-case capacity (what the
    # pre-tiled pipeline ran for the SAME stream content)
    mags, widths = main[1], main[3]
    pack_worst = timeit(lambda: bitpack.pack_blocks(mags, widths))
    pack_tiled = timeit(
        lambda: bitpack.pack_blocks_tiled(mags, widths, max_width=mw_main))

    comp = _pack_streams(main, rank, labels2b, n_cp, block=block,
                         mw_main=mw_main, mw_rank=mw_rank, backend=backend)
    restore = timeit(lambda: toposzp_decompress(comp, (ny, nx), EB,
                                                backend=backend))
    nblocks = int(widths.shape[0])
    cap_worst = nblocks * (((block - 1) * 32 + 7) // 8)
    cap_tiled = int(comp.szp.payload.shape[0])
    emit("fig7/core/stage_detect", detect * 1e6, {"backend": backend})
    emit("fig7/core/stage_quant", quant * 1e6,
         {"backend": backend, "includes": "cd+rp+qz+lz+widths"})
    emit("fig7/core/stage_pack", pack * 1e6, {
        "backend": backend,
        "width_bucket": mw_main,
        "tiled_vs_worstcase_time": pack_tiled / pack_worst,
    })
    emit("fig7/core/stage_restore", restore * 1e6, {"backend": backend})
    emit("fig7/core/be_capacity", 0.0, {
        "eb": EB, "grid": f"{ny}x{nx}",
        "cap_worstcase_bytes": cap_worst,
        "cap_tiled_bytes": cap_tiled,
        "capacity_reduction": cap_worst / cap_tiled,
        "payload_valid_bytes": int(comp.szp.payload_nbytes),
    })


def _resident_records(f: jnp.ndarray, backend: str) -> None:
    """Device-residency accounting for the resident compress path.

    ``d2h_bytes_per_compress`` / ``host_sync_count`` are structural, not
    sampled: the resident compress must (a) run under
    ``jax.transfer_guard("disallow")`` and (b) trace compress->decompress
    under ONE enclosing ``jax.jit`` — any hidden ``int(np.asarray(...))``
    width read or implicit transfer fails one of the two probes, and the
    record then reports the raw-field traffic the classic path would have
    moved, which trips the zero-tolerance gate."""
    eb = jnp.float32(EB)
    ny, nx = f.shape
    jax.block_until_ready(
        toposzp_compress(f, eb, resident=True, backend=backend))
    d2h_bytes, host_syncs = 0, 0
    try:
        with jax.transfer_guard("disallow"):
            jax.block_until_ready(
                toposzp_compress(f, eb, resident=True, backend=backend))

        @jax.jit
        def roundtrip(x, eb):
            parts = szp_compress(x, eb, resident=True, backend=backend)
            return szp_decompress(parts, (ny, nx), eb, backend=backend)

        jax.block_until_ready(roundtrip(f, eb))
    except Exception:
        d2h_bytes = f.size * 4          # the raw field would have crossed
        host_syncs = 1
    t_res = timeit(
        lambda: toposzp_compress(f, eb, resident=True, backend=backend))
    t_classic = timeit(lambda: toposzp_compress(f, EB, backend=backend))
    emit("fig7/core/device_resident", t_res * 1e6, {
        "backend": backend,
        "d2h_bytes_per_compress": d2h_bytes,
        "host_sync_count": host_syncs,
        "resident_vs_classic_time": t_res / t_classic,
    })


def _obs_overhead_record(f: jnp.ndarray, backend: str) -> None:
    """Obs-enabled vs obs-disabled compress+decompress time.

    The two sides are timed INTERLEAVED (min-of-5 pairs) so CPU frequency
    drift hits both equally; the CI gate (baseline_core.json) holds
    ``obs_vs_off`` at <= 1.05x — the spans/counters must stay noise-level
    on the classic hot path."""
    comp = toposzp_compress(f, EB, backend=backend)
    ny, nx = f.shape

    def fn():
        c = toposzp_compress(f, EB, backend=backend)
        return toposzp_decompress(comp, (ny, nx), EB, backend=backend), c

    was = obs.enabled()
    obs.set_enabled(False)
    jax.block_until_ready(fn())
    obs.set_enabled(True)
    jax.block_until_ready(fn())                           # warm both paths
    t_off = t_on = None
    for _ in range(5):
        obs.set_enabled(False)
        toff = timeit(fn, warmup=0, iters=1)
        obs.set_enabled(True)
        ton = timeit(fn, warmup=0, iters=1)
        t_off = toff if t_off is None else min(t_off, toff)
        t_on = ton if t_on is None else min(t_on, ton)
    obs.set_enabled(was)
    obs.reset()
    emit("fig7/core/obs_overhead", t_on * 1e6, {
        "backend": backend,
        "obs_vs_off": t_on / t_off,
    })


def run(smoke: bool = False):
    ny, nx = bench_grid("CLIMATE")
    backend = ops.resolve_backend(None)
    names = FIELDS[:2] if smoke else FIELDS
    iters = 2 if smoke else 6
    fields = []
    for i, field_name in enumerate(names):
        gen = gaussian_random_field if i % 2 == 0 else vortex_field
        fields.append(jnp.asarray(gen(ny, nx, seed=10 + i)))

    _stage_records(fields[0], backend)
    _resident_records(fields[0], backend)
    _obs_overhead_record(fields[0], backend)

    for f, field_name in zip(fields, names):
        comp = toposzp_compress(f, EB)
        t_fast_c = timeit(lambda: toposzp_compress(f, EB))
        t_fast_d = timeit(lambda: toposzp_decompress(comp, (ny, nx), EB))

        t_slow_c = timeit(lambda: topo_iter_compress(f, EB, max_iters=iters),
                          warmup=0, iters=1)
        slow_comp = topo_iter_compress(f, EB, max_iters=iters)
        t_slow_d = timeit(lambda: topo_iter_decompress(slow_comp, (ny, nx),
                                                       EB), warmup=0, iters=1)

        emit(f"fig7/{field_name}/toposzp_compress", t_fast_c * 1e6, {
            "speedup_vs_topoiter": t_slow_c / t_fast_c,
            "nbytes": int(comp.nbytes),
        })
        emit(f"fig7/{field_name}/toposzp_decompress", t_fast_d * 1e6,
             {"speedup_vs_topoiter": t_slow_d / t_fast_d})
        emit(f"fig7/{field_name}/topoiter_compress", t_slow_c * 1e6, "")
        emit(f"fig7/{field_name}/topoiter_decompress", t_slow_d * 1e6, "")

    # batched multi-field API vs a per-field loop (same streams); the two
    # sides are timed INTERLEAVED so CPU frequency drift hits both equally
    stack = jnp.stack(fields)
    loop_fn = lambda: [toposzp_compress(f, EB) for f in fields]  # noqa: E731
    batch_fn = lambda: toposzp_compress_batch(stack, EB)         # noqa: E731
    loop_fn(), batch_fn()                                        # warm both
    t_loop_c = t_batch_c = None
    for _ in range(3):
        tl = timeit(loop_fn, warmup=0, iters=1)
        tb = timeit(batch_fn, warmup=0, iters=1)
        t_loop_c = tl if t_loop_c is None else min(t_loop_c, tl)
        t_batch_c = tb if t_batch_c is None else min(t_batch_c, tb)
    bcomp = toposzp_compress_batch(stack, EB)
    t_batch_d = timeit(
        lambda: toposzp_decompress_batch(bcomp, (ny, nx), EB))
    emit("fig7/core/compress_batch", t_batch_c * 1e6, {
        "fields": len(fields),
        "batch_vs_loop": t_batch_c / t_loop_c,
        "us_per_field": t_batch_c * 1e6 / len(fields),
    })
    emit("fig7/core/decompress_batch", t_batch_d * 1e6, {
        "fields": len(fields),
        "us_per_field": t_batch_d * 1e6 / len(fields),
    })


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None,
                    help="write machine-readable results to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="fewer fields / TopoIter passes for CI wall-clock")
    args = ap.parse_args()
    reset_records()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
    if args.json:
        write_json(args.json, bench="bench_fig7_time", smoke=args.smoke)


if __name__ == "__main__":
    main()
