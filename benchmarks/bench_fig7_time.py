"""Paper Fig. 7: topology-aware compressor runtime comparison.

TopoSZp vs the TopoIter baseline (the TopoSZ/TopoA stand-in: iterative
global correction with persistence-style passes).  The paper reports
100x-10000x compression and 10x-500x decompression speedups for TopoSZp;
the derived column carries the measured speedup factors.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_grid, emit, timeit
from repro.core.baselines import (topo_iter_compress, topo_iter_decompress)
from repro.core.toposzp import toposzp_compress, toposzp_decompress
from repro.data.fields import gaussian_random_field, vortex_field

EB = 1e-3
FIELDS = ["AEROD", "CLDHGH", "CLDLOW", "FLDSC", "CLDMED"]   # ATM fields


def run():
    ny, nx = bench_grid("CLIMATE")
    for i, field_name in enumerate(FIELDS):
        gen = gaussian_random_field if i % 2 == 0 else vortex_field
        f = jnp.asarray(gen(ny, nx, seed=10 + i))

        comp = toposzp_compress(f, EB)
        t_fast_c = timeit(lambda: toposzp_compress(f, EB))
        t_fast_d = timeit(lambda: toposzp_decompress(comp, (ny, nx), EB))

        t_slow_c = timeit(lambda: topo_iter_compress(f, EB, max_iters=6),
                          warmup=0, iters=1)
        slow_comp = topo_iter_compress(f, EB, max_iters=6)
        t_slow_d = timeit(lambda: topo_iter_decompress(slow_comp, (ny, nx),
                                                       EB), warmup=0, iters=1)

        emit(f"fig7/{field_name}/toposzp_compress", t_fast_c * 1e6,
             f"speedup_vs_topoiter={t_slow_c / t_fast_c:.0f}x")
        emit(f"fig7/{field_name}/toposzp_decompress", t_fast_d * 1e6,
             f"speedup_vs_topoiter={t_slow_d / t_fast_d:.0f}x")
        emit(f"fig7/{field_name}/topoiter_compress", t_slow_c * 1e6, "")
        emit(f"fig7/{field_name}/topoiter_decompress", t_slow_d * 1e6, "")


if __name__ == "__main__":
    run()
