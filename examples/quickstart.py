"""Quickstart: compress a scientific field with topology guarantees.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (false_cases_host, max_abs_error, szp_roundtrip,
                        toposzp_roundtrip)
from repro.data.fields import vortex_field


def main():
    eb = 1e-3
    field = jnp.asarray(vortex_field(256, 320, n_vortices=60, seed=42))
    print(f"field: {field.shape}, raw {field.size * 4 / 1e6:.2f} MB, "
          f"error bound eps={eb}")

    # plain SZp: fast, error-bounded, but drops critical points (FN)
    rec_szp, parts = szp_roundtrip(field, eb)
    fc = false_cases_host(field, rec_szp)
    print(f"\nSZp     : ratio {field.size * 4 / int(parts.nbytes):5.2f}  "
          f"max_err {float(max_abs_error(field, rec_szp)):.2e}  "
          f"FN={fc['FN']} FP={fc['FP']} FT={fc['FT']}")

    # TopoSZp: same substrate + CD/RP metadata + stencil/RBF restoration
    rec, comp = toposzp_roundtrip(field, eb)
    fc2 = false_cases_host(field, rec)
    print(f"TopoSZp : ratio {field.size * 4 / int(comp.nbytes):5.2f}  "
          f"max_err {float(max_abs_error(field, rec)):.2e}  "
          f"FN={fc2['FN']} FP={fc2['FP']} FT={fc2['FT']}")

    print(f"\nFN reduction: {fc['FN']}/{max(fc2['FN'], 1)} = "
          f"{fc['FN'] / max(fc2['FN'], 1):.1f}x fewer missing critical "
          f"points; FP=FT=0 by construction; |err| <= 2 eps strictly.")


if __name__ == "__main__":
    main()
