"""Rate-distortion + topology sweep over CESM-like datasets, writing the
real on-disk byte format.

    PYTHONPATH=src python examples/compress_field.py [--dataset LAND]
"""
import argparse

import jax.numpy as jnp

from repro.core import false_cases_host, max_abs_error
from repro.core import io as cio
from repro.core.toposzp import toposzp_compress, toposzp_decompress
from repro.data.fields import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="LAND",
                    choices=["ATM", "CLIMATE", "ICE", "LAND", "OCEAN"])
    ap.add_argument("--out", default=None, help="write .tszp blobs here")
    args = ap.parse_args()

    fields = make_dataset(args.dataset, n_fields=3, seed=11)
    print(f"dataset {args.dataset}: {len(fields)} fields of "
          f"{fields[0].shape}")
    print(f"{'eb':>8} {'bitrate':>8} {'ratio':>7} {'max_err':>9} "
          f"{'FN':>6} {'FP':>3} {'FT':>3}")

    for eb in (1e-2, 1e-3, 1e-4):
        tot_bytes = tot_fn = tot_fp = tot_ft = 0
        max_err = 0.0
        for i, f in enumerate(fields):
            fj = jnp.asarray(f)
            comp = toposzp_compress(fj, eb)
            blob = cio.serialize_toposzp(comp, f.shape, eb)
            if args.out:
                import os
                os.makedirs(args.out, exist_ok=True)
                with open(f"{args.out}/{args.dataset}_{i}_eb{eb:.0e}.tszp",
                          "wb") as fh:
                    fh.write(blob)
            comp2, shape, eb2, block = cio.deserialize_toposzp(blob)
            rec = toposzp_decompress(comp2, shape, eb2, block=block)
            fc = false_cases_host(fj, rec)
            tot_bytes += len(blob)
            tot_fn += fc["FN"]; tot_fp += fc["FP"]; tot_ft += fc["FT"]
            max_err = max(max_err, float(max_abs_error(fj, rec)))
        n = sum(f.size for f in fields)
        print(f"{eb:8.0e} {8 * tot_bytes / n:8.3f} {4 * n / tot_bytes:7.2f} "
              f"{max_err:9.2e} {tot_fn:6d} {tot_fp:3d} {tot_ft:3d}"
              f"   (bound 2eb={2 * eb:.0e})")


if __name__ == "__main__":
    main()
