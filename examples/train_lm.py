"""End-to-end driver: train a ~100M-param MiniCPM-family model for a few
hundred steps with fault-tolerant checkpointing (and optional compressed
gradient all-reduce on a multi-device host).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.data import token_batches
from repro.models import lm, registry
from repro.optim import adamw, wsd
from repro.train import init_state, make_train_step, train_loop


def build_100m_cfg():
    """~100M-param llama-like config (MiniCPM family, WSD schedule)."""
    return registry.get_config("minicpm_2b").replace(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=8192, attn_chunk=256, loss_chunk=128,
        remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--tiny", action="store_true",
                    help="~4M params for very fast CPU demo")
    args = ap.parse_args()

    cfg = build_100m_cfg()
    if args.tiny:
        cfg = registry.get_smoke_config("minicpm_2b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n = lm.param_count(params)
    print(f"[train_lm] {cfg.name}-family model: {n / 1e6:.1f}M params")

    optimizer = adamw(wsd(args.lr, warmup=args.steps // 10,
                          stable=args.steps // 2, decay=args.steps // 2 + 1))
    state = init_state(params, optimizer, grad_compress=False)
    step_fn = make_train_step(cfg, optimizer)

    data = ({k: jnp.asarray(v) for k, v in b.items()}
            for b in token_batches(cfg, args.batch, args.seq, seed=0))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ck_")
    state, report = train_loop(state, step_fn, data, num_steps=args.steps,
                               ckpt_dir=ckpt_dir, ckpt_every=100,
                               log_every=25)
    import numpy as np
    print(f"[train_lm] loss {np.mean(report.losses[:10]):.4f} -> "
          f"{np.mean(report.losses[-10:]):.4f} "
          f"({report.steps_run} steps, ckpts at {report.checkpoints})")


if __name__ == "__main__":
    main()
