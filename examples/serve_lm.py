"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2_2b --steps 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import lm, registry
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.steps)

    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}

    t0 = time.perf_counter()
    tokens = engine.generate(batch, steps=args.steps)
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.steps
    print(f"[serve] {args.arch} (smoke config): generated "
          f"{tokens.shape} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. compile)")
    for i in range(min(args.batch, 2)):
        print(f"  request {i}: {tokens[i, :12].tolist()} ...")


if __name__ == "__main__":
    main()
