"""Serve a small model, greedy or continuous-batching.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2_2b --steps 24
    PYTHONPATH=src python examples/serve_lm.py --continuous --kv-mode toposzp
"""
import argparse
import time

import jax
import numpy as np

from repro.models import lm, registry
from repro.serve import ContinuousServeEngine, Request, ServeEngine


def run_greedy(cfg, params, args):
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.steps)
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    tokens = engine.generate({"tokens": prompts}, steps=args.steps)
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.steps
    print(f"[serve] {args.arch} (smoke config): generated "
          f"{tokens.shape} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. compile)")
    for i in range(min(args.batch, 2)):
        print(f"  request {i}: {tokens[i, :12].tolist()} ...")


def run_continuous(cfg, params, args):
    max_len = args.prompt_len + args.steps
    max_len += -max_len % 8                      # page-aligned
    engine = ContinuousServeEngine(cfg, params, max_len=max_len,
                                   num_slots=args.batch, page_size=8,
                                   kv_mode=args.kv_mode,
                                   verify_guarantees=args.kv_mode != "raw")
    reqs = []
    for i in range(2 * args.batch):              # mixed-length trace
        plen = max(4, args.prompt_len - 4 * (i % 3))
        toks = jax.random.randint(jax.random.PRNGKey(10 + i), (1, plen),
                                  0, cfg.vocab_size)
        reqs.append(Request(rid=i, inputs={"tokens": toks},
                            max_new_tokens=args.steps - (i % 3)))
    t0 = time.perf_counter()
    rep = engine.serve(reqs)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch} continuous kv_mode={args.kv_mode}: "
          f"{len(reqs)} requests, {rep.generated_tokens} tokens in "
          f"{rep.steps} steps / {dt:.2f}s incl. compile "
          f"(p50 step {1e3 * float(np.percentile(rep.step_times, 50)):.1f}ms)")
    if rep.kv_samples and args.kv_mode != "raw":
        peak = max(rep.kv_samples, key=lambda s: s["raw_equiv_bytes"])
        print(f"  KV at peak occupancy: {peak['resident_bytes']}B resident "
              f"vs {peak['raw_equiv_bytes']}B raw "
              f"({peak['cold_pages']}/{peak['occupied_pages']} pages cold); "
              f"guarantees: {engine.pool.stats}")
    for i in range(2):
        print(f"  request {i}: {rep.tokens[i][:12].tolist()} ...")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--continuous", action="store_true")
    ap.add_argument("--kv-mode", default="raw",
                    choices=("raw", "szp", "toposzp"))
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.continuous or args.kv_mode != "raw":
        run_continuous(cfg, params, args)
    else:
        run_greedy(cfg, params, args)


if __name__ == "__main__":
    main()
